//! Paged KV-cache pool: memory-accounted attention state for incremental
//! decode, with cross-request prefix sharing.
//!
//! The paper's decode loop re-runs the full growing prefix for every
//! generated token; TPI-LLM (arXiv:2410.00531) and EdgeInfinite
//! (arXiv:2503.22196) both observe that on edge devices the KV cache is
//! the dominant *dynamic* memory consumer, so attention state must live
//! under the same budget as the pipeline's weights — not in an
//! unaccounted side buffer.  This module is that budget citizen:
//!
//! * a [`KvPool`] holds the cached K/V tensors for one session's
//!   in-flight sequences, allocated in **blocks** of
//!   [`KvPool::block_tokens`] tokens per layer.  Every block is charged
//!   against the shared [`MemoryAccountant`] (the same one the Loading
//!   Agents admit weights through) and additionally capped by the pool's
//!   own `kv_budget` — the per-lane allocation a
//!   [`crate::server::Router`] grants so one model's long generations
//!   cannot starve another model's weights or KV;
//! * a [`KvSeq`] is one sequence's RAII handle: dropping it (request
//!   completion or rejection) releases its references; a block's bytes
//!   return to the budget when its **last** holder lets go;
//! * blocks are **content-hashed and refcounted**: when a committed,
//!   fully-covered block's K/V content matches an already-sealed block
//!   (vLLM-style prefix caching, keyed by content rather than token ids
//!   so sharing can never change what `dense_kv` returns), the private
//!   copy is freed back to the accountant and the sequence references the
//!   shared block instead — N requests decoding the same system prompt
//!   charge the accountant once.  Writes into a shared (or sealed) block
//!   **copy-on-write** so divergence never corrupts a neighbour;
//! * under `S^stop` pressure the pool is an eviction target of the
//!   [`crate::pipeload::gate::OrderedGate`], alongside pinned hot
//!   layers: [`KvPool::evict_for`] reclaims whole sequences with
//!   **refcount-aware victim selection** — LRU among sequences whose
//!   eviction actually frees bytes first (a sequence holding only shared
//!   blocks frees nothing until its peers go), so reclaim makes progress
//!   instead of shredding shared prefixes for zero gain.  An evicted
//!   sequence is marked invalid, **not** an error — the decode loop falls
//!   back to a full-prefix recompute, so tokens stay bit-identical to
//!   sharing-off.
//!
//! Allocation never blocks: block grants use
//! [`MemoryAccountant::try_acquire`] (after trying to evict *other*
//! sequences), because the grab happens on the inference thread in the
//! middle of a pass — parking there would deadlock the pipeline that is
//! supposed to free the memory.  A failed grant degrades to uncached
//! decode, it never stalls.  A failed copy-on-write grant likewise
//! degrades: the writing sequence is invalidated and recomputes.
//!
//! K/V data is stored block-major (`[block_tokens][batch][hidden]` per
//! layer-block) so appending one decoded token is a row write into the
//! tail block; [`KvPool::dense_kv`] re-packs a layer into the
//! `[batch, seq, hidden]` buffers the `*_inc` HLO entries take,
//! zero-filling past the cached prefix (the entries mask attention at
//! `pos`, so the padding is inert).

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::memory::MemoryAccountant;
use crate::telemetry::{worker, EvArgs, Telemetry};

/// Default tokens per block (allocation granularity).  Small enough that
/// tiny test profiles (`max_seq` 16) exercise multi-block sequences.
pub const DEFAULT_BLOCK_TOKENS: usize = 8;

/// Pool counters (surfaced through `RunReport` / `ServeSummary` /
/// `serve --json`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvPoolStats {
    /// blocks ever granted (fresh allocations, including COW copies)
    pub allocated_blocks: u64,
    /// block references reclaimed under `S^stop` pressure (gate eviction)
    pub evicted_blocks: u64,
    /// unique bytes currently accounted by the pool (shared blocks once)
    pub pool_bytes: u64,
    /// unique blocks currently held
    pub pool_blocks: u64,
    /// sequences currently registered (valid or evicted-but-open)
    pub sequences: usize,
    /// blocks currently referenced by more than one sequence
    pub shared_blocks: u64,
    /// cumulative sharing events (a block gaining an extra holder)
    pub shared_total: u64,
    /// cumulative bytes returned to the budget by content dedup
    pub dedup_bytes: u64,
}

/// One layer-block: `block_tokens` positions of K and V for one layer.
#[derive(Debug)]
struct Block {
    k: Vec<f32>,
    v: Vec<f32>,
    /// sequences referencing this block
    refs: u32,
    bytes: u64,
    /// content hash once sealed (immutable + dedup-eligible); `None`
    /// while the block is still private and writable in place
    hash: Option<u64>,
}

#[derive(Debug)]
struct SeqState {
    /// per-layer block-id lists; slot `i` covers tokens
    /// `[i*block_tokens, (i+1)*block_tokens)`
    blocks: Vec<Vec<u64>>,
    batch: usize,
    hidden: usize,
    /// cached prefix length in tokens (positions `0..tokens` are valid)
    tokens: usize,
    /// reserved capacity in tokens (grows in whole blocks)
    capacity: usize,
    /// LRU clock of the last reserve/advance (eviction victim = smallest)
    last_use: u64,
    /// cleared by eviction: data is gone, owner must recompute
    valid: bool,
}

impl SeqState {
    fn layers(&self) -> usize {
        self.blocks.len()
    }
}

#[derive(Debug, Default)]
struct PoolState {
    seqs: HashMap<u64, SeqState>,
    blocks: HashMap<u64, Block>,
    /// content hash -> sealed block id (dedup registry; stale entries are
    /// removed when their block dies)
    by_hash: HashMap<u64, u64>,
    next_seq: u64,
    next_block: u64,
    clock: u64,
    /// unique bytes accounted (shared blocks counted once)
    used: u64,
    /// unique blocks held
    held_blocks: u64,
    allocated_blocks: u64,
    evicted_blocks: u64,
    shared_total: u64,
    dedup_bytes: u64,
    /// pool-level byte cap (the lane's KV allocation); `None` = only the
    /// accountant's budget constrains the pool.  Mutable at run time —
    /// elastic budget steps rebalance it via [`KvPool::set_kv_budget`].
    kv_budget: Option<u64>,
}

impl PoolState {
    /// Drop one reference to `bid`; frees the block (returning its bytes)
    /// when this was the last holder.
    fn decref(&mut self, bid: u64) -> u64 {
        let Some(b) = self.blocks.get_mut(&bid) else { return 0 };
        b.refs -= 1;
        if b.refs > 0 {
            return 0;
        }
        let block = self.blocks.remove(&bid).unwrap();
        if let Some(h) = block.hash {
            if self.by_hash.get(&h) == Some(&bid) {
                self.by_hash.remove(&h);
            }
        }
        self.used -= block.bytes;
        self.held_blocks -= 1;
        block.bytes
    }

    /// Drop one sequence's storage and return `(freed_bytes,
    /// released_block_refs)`, without removing the entry (eviction keeps
    /// the tombstone so the owner can observe the invalidation; release
    /// removes it entirely).  `freed_bytes` counts only blocks whose last
    /// reference this was — shared blocks survive with their peers.
    fn strip(&mut self, id: u64) -> (u64, u64) {
        let Some(seq) = self.seqs.get_mut(&id) else { return (0, 0) };
        let lists = std::mem::take(&mut seq.blocks);
        let layers = lists.len();
        seq.blocks = vec![Vec::new(); layers];
        seq.tokens = 0;
        seq.capacity = 0;
        seq.valid = false;
        let mut freed = 0u64;
        let mut released = 0u64;
        for list in lists {
            for bid in list {
                released += 1;
                freed += self.decref(bid);
            }
        }
        (freed, released)
    }

    /// Bytes a sequence's eviction would actually free right now (its
    /// privately-held blocks; shared blocks free nothing until the last
    /// holder goes).
    fn freeable(&self, seq: &SeqState) -> u64 {
        seq.blocks
            .iter()
            .flatten()
            .filter_map(|bid| self.blocks.get(bid))
            .filter(|b| b.refs == 1)
            .map(|b| b.bytes)
            .sum()
    }
}

/// FNV-1a over the K/V content plus the row geometry, so blocks only ever
/// dedup against blocks whose `dense_kv` reads would be bit-identical.
fn content_hash(k: &[f32], v: &[f32], batch: usize, hidden: usize) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    eat(batch as u64);
    eat(hidden as u64);
    eat(k.len() as u64);
    for &f in k {
        eat(f.to_bits() as u64);
    }
    for &f in v {
        eat(f.to_bits() as u64);
    }
    h
}

/// Shared paged KV pool; clone freely (Arc inside).  One per session.
#[derive(Debug, Clone)]
pub struct KvPool {
    accountant: MemoryAccountant,
    block_tokens: usize,
    inner: Arc<Mutex<PoolState>>,
    /// Write-once event bus slot shared by every clone: the pool is cloned
    /// into gates and victim chains before serving starts, so a plain
    /// per-clone field could never reach them all after the fact.
    telemetry: Arc<OnceLock<Telemetry>>,
}

impl KvPool {
    pub fn new(accountant: MemoryAccountant, kv_budget: Option<u64>) -> KvPool {
        KvPool::with_block_tokens(accountant, kv_budget, DEFAULT_BLOCK_TOKENS)
    }

    pub fn with_block_tokens(
        accountant: MemoryAccountant,
        kv_budget: Option<u64>,
        block_tokens: usize,
    ) -> KvPool {
        KvPool {
            accountant,
            block_tokens: block_tokens.max(1),
            inner: Arc::new(Mutex::new(PoolState { kv_budget, ..PoolState::default() })),
            telemetry: Arc::new(OnceLock::new()),
        }
    }

    /// Attach the structured event bus.  Write-once across all clones
    /// (later calls are ignored); reading the slot is a cheap atomic, so
    /// the disabled path stays near-free.
    pub fn set_telemetry(&self, t: Telemetry) {
        let _ = self.telemetry.set(t);
    }

    fn tel(&self) -> Option<&Telemetry> {
        self.telemetry.get().filter(|t| t.is_on())
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn kv_budget(&self) -> Option<u64> {
        self.inner.lock().unwrap().kv_budget
    }

    /// Retarget the pool cap (elastic budget step).  Shrinking below the
    /// currently held bytes evicts whole sequences until the pool fits the
    /// new cap — refcount-aware LRU: sequences whose eviction actually
    /// frees bytes go first (their owners fall back to full-prefix
    /// recompute — degraded, never wrong); growing widens future reserve
    /// headroom.  Returns bytes freed.
    pub fn set_kv_budget(&self, new_budget: Option<u64>) -> u64 {
        let mut freed = 0u64;
        loop {
            let mut s = self.inner.lock().unwrap();
            s.kv_budget = new_budget;
            let Some(cap) = new_budget else { return freed };
            if s.used <= cap {
                return freed;
            }
            let victim = s
                .seqs
                .iter()
                .filter(|(_, q)| q.valid && q.blocks.iter().any(|l| !l.is_empty()))
                .min_by_key(|(_, q)| (s.freeable(q) == 0, q.last_use))
                .map(|(id, _)| *id);
            let Some(vid) = victim else { return freed };
            let (b, blocks) = s.strip(vid);
            s.evicted_blocks += blocks;
            drop(s);
            if b > 0 {
                self.accountant.free(b);
            }
            freed += b;
        }
    }

    /// Bytes of one block: `block_tokens` positions of K **and** V for one
    /// layer at the given (batch, hidden).
    fn block_bytes(&self, batch: usize, hidden: usize) -> u64 {
        (self.block_tokens * batch * hidden * 4 * 2) as u64
    }

    /// Register a new sequence (no memory is granted yet); returns its
    /// RAII handle.  `layers` is the number of body layers caching K/V.
    pub fn open_seq(&self, layers: usize, batch: usize, hidden: usize) -> KvSeq {
        let mut s = self.inner.lock().unwrap();
        let id = s.next_seq;
        s.next_seq += 1;
        s.clock += 1;
        let clock = s.clock;
        s.seqs.insert(
            id,
            SeqState {
                blocks: vec![Vec::new(); layers],
                batch,
                hidden,
                tokens: 0,
                capacity: 0,
                last_use: clock,
                valid: true,
            },
        );
        KvSeq { pool: self.clone(), id }
    }

    /// Open a new sequence sharing `parent`'s committed, sealed prefix
    /// blocks (each gains a reference; no bytes are charged).  The child
    /// starts with `tokens` = the shared whole-block prefix and diverges
    /// via copy-on-write the moment it writes into the shared region.
    /// `None` if the parent is gone, evicted, or has no sealed prefix yet.
    fn fork_from(&self, parent: u64) -> Option<KvSeq> {
        let mut s = self.inner.lock().unwrap();
        s.clock += 1;
        let clock = s.clock;
        let p = s.seqs.get(&parent)?;
        if !p.valid || p.layers() == 0 {
            return None;
        }
        let (batch, hidden, layers) = (p.batch, p.hidden, p.layers());
        // sharable prefix: whole blocks inside the committed prefix that
        // every layer has sealed (a COW may have unsealed one layer's copy)
        let full = p.tokens / self.block_tokens;
        let mut share = full;
        for l in 0..layers {
            let sealed = p.blocks[l]
                .iter()
                .take(full)
                .take_while(|bid| s.blocks.get(bid).map(|b| b.hash.is_some()).unwrap_or(false))
                .count();
            share = share.min(sealed);
        }
        if share == 0 {
            return None;
        }
        let lists: Vec<Vec<u64>> =
            (0..layers).map(|l| p.blocks[l][..share].to_vec()).collect();
        for bid in lists.iter().flatten() {
            let b = s.blocks.get_mut(bid).unwrap();
            b.refs += 1;
            if b.refs == 2 {
                s.shared_total += 1;
            }
        }
        let id = s.next_seq;
        s.next_seq += 1;
        s.seqs.insert(
            id,
            SeqState {
                blocks: lists,
                batch,
                hidden,
                tokens: share * self.block_tokens,
                capacity: share * self.block_tokens,
                last_use: clock,
                valid: true,
            },
        );
        Some(KvSeq { pool: self.clone(), id })
    }

    /// Grow a sequence's reserved capacity to at least `tokens` positions.
    /// Grants whole blocks across every layer, charged to the accountant
    /// (non-blocking) and the pool budget.  On budget pressure it first
    /// evicts *other* sequences (refcount-aware LRU).  `false` = could not
    /// reserve; the sequence stays as it was (caller decodes uncached).
    fn reserve(&self, id: u64, tokens: usize) -> bool {
        let (want, need_blocks, new_capacity, per_block, row) = {
            let mut s = self.inner.lock().unwrap();
            s.clock += 1;
            let clock = s.clock;
            let Some(seq) = s.seqs.get_mut(&id) else { return false };
            if !seq.valid {
                return false;
            }
            seq.last_use = clock;
            if tokens <= seq.capacity {
                return true;
            }
            let new_capacity = tokens.div_ceil(self.block_tokens) * self.block_tokens;
            let need_blocks = (new_capacity - seq.capacity) / self.block_tokens * seq.layers();
            let per_block = self.block_bytes(seq.batch, seq.hidden);
            let want = need_blocks as u64 * per_block;
            if let Some(cap) = s.kv_budget {
                if s.used + want > cap {
                    return false;
                }
            }
            (want, need_blocks, new_capacity, per_block, seq.batch * seq.hidden)
        };
        // Take the grant outside the pool lock; under pressure, evict other
        // sequences first (never this one), then retry once.  Never block:
        // this runs on the inference thread mid-pass.
        if !self.accountant.try_acquire(want) {
            self.evict_lru_except(Some(id), want);
            if !self.accountant.try_acquire(want) {
                return false;
            }
        }
        let mut s = self.inner.lock().unwrap();
        let ok = s.seqs.get(&id).map(|seq| seq.valid).unwrap_or(false);
        if !ok {
            // evicted/released between locks: hand the grant straight back
            drop(s);
            self.accountant.free(want);
            return false;
        }
        let elems = self.block_tokens * row;
        let mut fresh: Vec<u64> = Vec::with_capacity(need_blocks);
        for _ in 0..need_blocks {
            let bid = s.next_block;
            s.next_block += 1;
            s.blocks.insert(
                bid,
                Block {
                    k: vec![0.0; elems],
                    v: vec![0.0; elems],
                    refs: 1,
                    bytes: per_block,
                    hash: None,
                },
            );
            fresh.push(bid);
        }
        let layers = s.seqs.get(&id).unwrap().layers();
        let per_layer = if layers == 0 { 0 } else { need_blocks / layers };
        let seq = s.seqs.get_mut(&id).unwrap();
        seq.capacity = new_capacity;
        let mut it = fresh.into_iter();
        for l in 0..layers {
            for _ in 0..per_layer {
                seq.blocks[l].push(it.next().unwrap());
            }
        }
        s.used += want;
        s.held_blocks += need_blocks as u64;
        s.allocated_blocks += need_blocks as u64;
        true
    }

    /// Make `seq.blocks[layer][idx]` privately writable, copy-on-write if
    /// it is currently shared.  A sealed private block is unsealed (its
    /// dedup registration dropped) instead of copied.  Returns the block
    /// id, or `None` when the COW grant failed — the caller strips the
    /// sequence (degrade to recompute; never corrupt a peer).
    fn writable_block(&self, s: &mut PoolState, id: u64, layer: usize, idx: usize) -> Option<u64> {
        let seq = s.seqs.get(&id)?;
        let bid = *seq.blocks.get(layer)?.get(idx)?;
        let (refs, bytes, sealed) = {
            let b = s.blocks.get(&bid)?;
            (b.refs, b.bytes, b.hash.is_some())
        };
        if refs == 1 {
            if sealed {
                let b = s.blocks.get_mut(&bid).unwrap();
                let h = b.hash.take().unwrap();
                if s.by_hash.get(&h) == Some(&bid) {
                    s.by_hash.remove(&h);
                }
            }
            return Some(bid);
        }
        // shared: divergence needs a private copy, charged like any grant
        if let Some(cap) = s.kv_budget {
            if s.used + bytes > cap {
                return None;
            }
        }
        if !self.accountant.try_acquire(bytes) {
            return None;
        }
        let (k, v) = {
            let b = s.blocks.get(&bid).unwrap();
            (b.k.clone(), b.v.clone())
        };
        let nid = s.next_block;
        s.next_block += 1;
        s.blocks.insert(nid, Block { k, v, refs: 1, bytes, hash: None });
        s.used += bytes;
        s.held_blocks += 1;
        s.allocated_blocks += 1;
        s.decref(bid); // refs >= 2, so this never frees
        s.seqs.get_mut(&id).unwrap().blocks[layer][idx] = nid;
        if let Some(t) = self.tel() {
            t.instant("kv_cow", worker::INFER, EvArgs::default().with_bytes(bytes));
        }
        Some(nid)
    }

    /// Write one token's K/V rows for one layer at position `pos`
    /// (row-major rows: `batch * hidden` values each).  Silently ignored
    /// if the sequence was evicted mid-pass — the pass still completes,
    /// only the cache write is lost.  A failed copy-on-write invalidates
    /// the sequence (recompute fallback), never a peer.
    fn write_token(&self, id: u64, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let mut s = self.inner.lock().unwrap();
        let Some(seq) = s.seqs.get(&id) else { return };
        if !seq.valid || pos >= seq.capacity || layer >= seq.layers() {
            return;
        }
        let row = seq.batch * seq.hidden;
        debug_assert_eq!(k.len(), row);
        debug_assert_eq!(v.len(), row);
        let idx = pos / self.block_tokens;
        let off = pos % self.block_tokens;
        match self.writable_block(&mut s, id, layer, idx) {
            Some(bid) => {
                let b = s.blocks.get_mut(&bid).unwrap();
                b.k[off * row..(off + 1) * row].copy_from_slice(k);
                b.v[off * row..(off + 1) * row].copy_from_slice(v);
            }
            None => {
                let (freed, _) = s.strip(id);
                drop(s);
                if freed > 0 {
                    self.accountant.free(freed);
                }
            }
        }
    }

    /// Bulk-write positions `0..tokens` of one layer (the full-prefix
    /// prime).  `k`/`v` are token-major `[tokens][batch][hidden]`.
    fn write_prefix(&self, id: u64, layer: usize, tokens: usize, k: &[f32], v: &[f32]) {
        let mut s = self.inner.lock().unwrap();
        let Some(seq) = s.seqs.get(&id) else { return };
        if !seq.valid || tokens > seq.capacity || layer >= seq.layers() {
            return;
        }
        let row = seq.batch * seq.hidden;
        debug_assert_eq!(k.len(), tokens * row);
        debug_assert_eq!(v.len(), tokens * row);
        let mut pos = 0usize;
        while pos < tokens {
            let idx = pos / self.block_tokens;
            let take = (self.block_tokens - pos % self.block_tokens).min(tokens - pos);
            match self.writable_block(&mut s, id, layer, idx) {
                Some(bid) => {
                    let off = pos % self.block_tokens;
                    let b = s.blocks.get_mut(&bid).unwrap();
                    b.k[off * row..(off + take) * row]
                        .copy_from_slice(&k[pos * row..(pos + take) * row]);
                    b.v[off * row..(off + take) * row]
                        .copy_from_slice(&v[pos * row..(pos + take) * row]);
                }
                None => {
                    let (freed, _) = s.strip(id);
                    drop(s);
                    if freed > 0 {
                        self.accountant.free(freed);
                    }
                    return;
                }
            }
            pos += take;
        }
    }

    /// Commit the cached prefix length (only after a pass fully succeeds,
    /// so a failed pass can never leave a half-written prefix readable),
    /// then seal + dedup every block the committed prefix fully covers:
    /// an identical already-sealed block absorbs this sequence's reference
    /// and the private copy's bytes go back to the budget.
    fn set_tokens(&self, id: u64, tokens: usize) {
        let mut s = self.inner.lock().unwrap();
        s.clock += 1;
        let clock = s.clock;
        let Some(seq) = s.seqs.get_mut(&id) else { return };
        if !seq.valid || tokens > seq.capacity {
            return;
        }
        seq.tokens = tokens;
        seq.last_use = clock;
        let (batch, hidden, layers) = (seq.batch, seq.hidden, seq.layers());
        let full = tokens / self.block_tokens;
        let mut refund = 0u64;
        for l in 0..layers {
            for idx in 0..full.min(s.seqs.get(&id).unwrap().blocks[l].len()) {
                let bid = s.seqs.get(&id).unwrap().blocks[l][idx];
                let (sealed, refs) = {
                    let b = s.blocks.get(&bid).unwrap();
                    (b.hash.is_some(), b.refs)
                };
                if sealed {
                    continue; // already sealed (shared or previously committed)
                }
                debug_assert_eq!(refs, 1, "unsealed blocks are private");
                let h = {
                    let b = s.blocks.get(&bid).unwrap();
                    content_hash(&b.k, &b.v, batch, hidden)
                };
                let existing = s.by_hash.get(&h).copied().filter(|eid| {
                    *eid != bid
                        && s.blocks.get(eid).map(|e| {
                            let mine = s.blocks.get(&bid).unwrap();
                            e.hash == Some(h) && e.k == mine.k && e.v == mine.v
                        }) == Some(true)
                });
                match existing {
                    Some(eid) => {
                        // content dedup: drop the private copy, ref the twin
                        let b = s.decref(bid);
                        refund += b;
                        s.dedup_bytes += b;
                        let e = s.blocks.get_mut(&eid).unwrap();
                        e.refs += 1;
                        if e.refs == 2 {
                            s.shared_total += 1;
                        }
                        s.seqs.get_mut(&id).unwrap().blocks[l][idx] = eid;
                    }
                    None => {
                        s.blocks.get_mut(&bid).unwrap().hash = Some(h);
                        s.by_hash.insert(h, bid);
                    }
                }
            }
        }
        drop(s);
        if refund > 0 {
            self.accountant.free(refund);
            if let Some(t) = self.tel() {
                t.instant("kv_dedup", worker::INFER, EvArgs::default().with_bytes(refund));
            }
        }
    }

    /// Re-pack one layer's cached K/V into dense `[batch, seq_len, hidden]`
    /// buffers (zero past the prefix), for upload to an `*_inc` entry.
    /// `None` if the sequence is gone or was evicted.
    fn dense_kv(&self, id: u64, layer: usize, seq_len: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        let s = self.inner.lock().unwrap();
        let seq = s.seqs.get(&id)?;
        if !seq.valid || layer >= seq.layers() {
            return None;
        }
        let (b, h) = (seq.batch, seq.hidden);
        let t = seq.tokens.min(seq_len);
        let mut dk = vec![0.0f32; b * seq_len * h];
        let mut dv = vec![0.0f32; b * seq_len * h];
        for tok in 0..t {
            let block = s.blocks.get(&seq.blocks[layer][tok / self.block_tokens])?;
            let off = tok % self.block_tokens;
            for row in 0..b {
                let src = off * b * h + row * h;
                let dst = row * seq_len * h + tok * h;
                dk[dst..dst + h].copy_from_slice(&block.k[src..src + h]);
                dv[dst..dst + h].copy_from_slice(&block.v[src..src + h]);
            }
        }
        Some((dk, dv))
    }

    fn seq_tokens(&self, id: u64) -> Option<usize> {
        let s = self.inner.lock().unwrap();
        s.seqs.get(&id).filter(|q| q.valid).map(|q| q.tokens)
    }

    fn seq_valid(&self, id: u64) -> bool {
        let s = self.inner.lock().unwrap();
        s.seqs.get(&id).map(|q| q.valid).unwrap_or(false)
    }

    /// Drop a sequence's storage without removing it (the owner sees
    /// `valid() == false` and recomputes).  Used on pass failure.
    fn invalidate(&self, id: u64) {
        let mut s = self.inner.lock().unwrap();
        let (bytes, _) = s.strip(id);
        drop(s);
        if bytes > 0 {
            self.accountant.free(bytes);
        }
    }

    /// Remove a sequence entirely, returning its block references
    /// (request completion/rejection; `KvSeq::drop` calls this).  Bytes go
    /// back to the budget when the last holder of each block lets go.
    fn release(&self, id: u64) {
        let mut s = self.inner.lock().unwrap();
        let (bytes, _) = s.strip(id);
        s.seqs.remove(&id);
        drop(s);
        if bytes > 0 {
            self.accountant.free(bytes);
        }
    }

    /// Evict sequences (optionally sparing one) until either `bytes` fit
    /// the accountant's budget or nothing is left.  Victim order is
    /// refcount-aware LRU: sequences whose eviction actually frees bytes
    /// first, least-recently-used within; all-shared sequences go last
    /// (stripping them is what makes their peers' blocks freeable next
    /// round, so the loop still terminates).  Returns bytes freed.
    /// Evicted sequences keep a tombstone entry so their owners observe
    /// the invalidation and fall back to full-prefix recompute.
    fn evict_lru_except(&self, spare: Option<u64>, bytes: u64) -> u64 {
        let mut freed = 0u64;
        loop {
            if !self.accountant.would_block(bytes) {
                break;
            }
            let mut s = self.inner.lock().unwrap();
            let victim = s
                .seqs
                .iter()
                .filter(|(id, q)| {
                    q.valid
                        && q.blocks.iter().any(|l| !l.is_empty())
                        && Some(**id) != spare
                })
                .min_by_key(|(_, q)| (s.freeable(q) == 0, q.last_use))
                .map(|(id, _)| *id);
            let Some(vid) = victim else { break };
            let (b, blocks) = s.strip(vid);
            s.evicted_blocks += blocks;
            drop(s);
            self.accountant.free(b);
            freed += b;
        }
        freed
    }

    /// Strip every sequence's storage and return all unique bytes to the
    /// accountant, keeping tombstones so owners observe the invalidation
    /// (failed-pass recovery: the session must release exactly its own
    /// bytes without guessing which sequences were mid-flight).  Returns
    /// bytes freed.
    pub fn invalidate_all(&self) -> u64 {
        let mut s = self.inner.lock().unwrap();
        let mut freed = 0u64;
        let ids: Vec<u64> = s.seqs.keys().copied().collect();
        for id in ids {
            let (bytes, _) = s.strip(id);
            freed += bytes;
        }
        drop(s);
        if freed > 0 {
            self.accountant.free(freed);
        }
        freed
    }

    /// `S^stop` pressure valve (gate eviction target, like
    /// [`crate::pipeload::cache::LayerCache::evict_for`]): evict whole
    /// sequences refcount-aware-LRU-first until `bytes` fit this pool's
    /// accountant — which is the same shared accountant the gate admits
    /// against, by construction.  Returns bytes freed.
    pub fn evict_for(&self, bytes: u64) -> u64 {
        self.evict_lru_except(None, bytes)
    }

    /// Unique bytes currently accounted by the pool.
    pub fn used_bytes(&self) -> u64 {
        self.inner.lock().unwrap().used
    }

    pub fn stats(&self) -> KvPoolStats {
        let s = self.inner.lock().unwrap();
        KvPoolStats {
            allocated_blocks: s.allocated_blocks,
            evicted_blocks: s.evicted_blocks,
            pool_bytes: s.used,
            pool_blocks: s.held_blocks,
            sequences: s.seqs.len(),
            shared_blocks: s.blocks.values().filter(|b| b.refs > 1).count() as u64,
            shared_total: s.shared_total,
            dedup_bytes: s.dedup_bytes,
        }
    }
}

/// RAII handle to one sequence's cached K/V.  Dropping it releases every
/// block reference — the per-request lifecycle the Router relies on
/// (blocks are gone when the ticket resolves, served or rejected; a block
/// shared with a live peer survives until its last holder drops).
#[derive(Debug)]
pub struct KvSeq {
    pool: KvPool,
    id: u64,
}

impl KvSeq {
    /// Cached prefix length (`None`/0 once evicted).
    pub fn tokens(&self) -> usize {
        self.pool.seq_tokens(self.id).unwrap_or(0)
    }

    /// False once the gate (or a failed pass) reclaimed this sequence.
    pub fn valid(&self) -> bool {
        self.pool.seq_valid(self.id)
    }

    /// Ensure capacity for a prefix of `tokens` positions (block-granular,
    /// non-blocking).  `false` = budget pressure; decode uncached.
    pub fn reserve(&self, tokens: usize) -> bool {
        self.pool.reserve(self.id, tokens)
    }

    pub fn write_token(&self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.pool.write_token(self.id, layer, pos, k, v);
    }

    pub fn write_prefix(&self, layer: usize, tokens: usize, k: &[f32], v: &[f32]) {
        self.pool.write_prefix(self.id, layer, tokens, k, v);
    }

    pub fn set_tokens(&self, tokens: usize) {
        self.pool.set_tokens(self.id, tokens);
    }

    pub fn dense_kv(&self, layer: usize, seq_len: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        self.pool.dense_kv(self.id, layer, seq_len)
    }

    /// Open a sibling sequence sharing this one's committed, sealed
    /// whole-block prefix (refcounted, zero extra bytes).  The sibling
    /// copy-on-writes the moment it diverges.  `None` when there is no
    /// sealed prefix to share.
    pub fn fork(&self) -> Option<KvSeq> {
        self.pool.fork_from(self.id)
    }

    /// Drop the cached data (kept registered, marked invalid).
    pub fn invalidate(&self) {
        self.pool.invalidate(self.id);
    }
}

impl Drop for KvSeq {
    fn drop(&mut self) {
        self.pool.release(self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(budget: Option<u64>, kv_budget: Option<u64>) -> (KvPool, MemoryAccountant) {
        let a = MemoryAccountant::new(budget);
        (KvPool::with_block_tokens(a.clone(), kv_budget, 4), a)
    }

    #[test]
    fn reserve_charges_blocks_and_release_refunds() {
        let (p, a) = pool(Some(100_000), None);
        let seq = p.open_seq(2, 1, 8); // 2 layers, B=1, H=8
        // block = 4 tokens * 1 * 8 * 4 B * 2(K+V) = 256 B; 2 layers = 512 B
        assert!(seq.reserve(1));
        assert_eq!(a.used(), 512);
        assert_eq!(p.used_bytes(), 512);
        assert_eq!(p.stats().pool_blocks, 2);
        // within the same block: no new charge
        assert!(seq.reserve(4));
        assert_eq!(a.used(), 512);
        // fifth token needs a second block row across both layers
        assert!(seq.reserve(5));
        assert_eq!(a.used(), 1024);
        assert_eq!(p.stats().allocated_blocks, 4);
        drop(seq);
        assert_eq!(a.used(), 0);
        assert_eq!(p.stats().sequences, 0);
    }

    #[test]
    fn kv_budget_caps_pool_even_with_accountant_headroom() {
        let (p, a) = pool(Some(1_000_000), Some(600));
        let seq = p.open_seq(2, 1, 8); // 512 B per block row
        assert!(seq.reserve(4));
        assert!(!seq.reserve(5), "second block row would exceed the 600 B kv budget");
        assert_eq!(a.used(), 512);
        // the failed reserve must not have leaked anything
        assert_eq!(p.used_bytes(), 512);
        assert!(seq.valid());
        assert_eq!(seq.tokens(), 0);
    }

    #[test]
    fn write_commit_dense_roundtrip() {
        let (p, _a) = pool(None, None);
        let seq = p.open_seq(1, 2, 4); // 1 layer, B=2, H=4
        assert!(seq.reserve(2));
        // prime position 0 for both rows, then append position 1
        let k0: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let v0: Vec<f32> = (0..8).map(|i| 10.0 + i as f32).collect();
        seq.write_prefix(0, 1, &k0, &v0);
        seq.set_tokens(1);
        let k1: Vec<f32> = (0..8).map(|i| 100.0 + i as f32).collect();
        let v1: Vec<f32> = (0..8).map(|i| 110.0 + i as f32).collect();
        seq.write_token(0, 1, &k1, &v1);
        seq.set_tokens(2);
        assert_eq!(seq.tokens(), 2);
        let (dk, dv) = seq.dense_kv(0, 3).unwrap(); // dense [2, 3, 4]
        // row 0: tokens 0,1 then zero padding
        assert_eq!(&dk[0..4], &k0[0..4]);
        assert_eq!(&dk[4..8], &k1[0..4]);
        assert_eq!(&dk[8..12], &[0.0; 4]);
        // row 1 lives at stride seq_len*H = 12
        assert_eq!(&dk[12..16], &k0[4..8]);
        assert_eq!(&dv[16..20], &v1[4..8]);
    }

    #[test]
    fn eviction_invalidates_lru_sequence_and_frees_budget() {
        let (p, a) = pool(Some(1100), None);
        let old = p.open_seq(1, 1, 8); // block = 256 B
        let newer = p.open_seq(1, 1, 8);
        assert!(old.reserve(4));
        assert!(newer.reserve(4));
        assert_eq!(a.used(), 512);
        // an outside admission of 800 B needs 212 B reclaimed -> evict `old`
        let freed = p.evict_for(800);
        assert_eq!(freed, 256);
        assert!(!old.valid());
        assert!(newer.valid());
        assert_eq!(p.stats().evicted_blocks, 1);
        assert_eq!(a.used(), 256);
        // evicted sequence degrades gracefully
        assert_eq!(old.tokens(), 0);
        assert!(old.dense_kv(0, 4).is_none());
        assert!(!old.reserve(1));
        old.write_token(0, 0, &[0.0; 8], &[0.0; 8]); // ignored, no panic
    }

    #[test]
    fn reserve_evicts_other_sequences_before_failing() {
        let (p, a) = pool(Some(512), None);
        let a_seq = p.open_seq(1, 1, 8);
        assert!(a_seq.reserve(4)); // 256 B
        let b_seq = p.open_seq(1, 1, 8);
        assert!(b_seq.reserve(4)); // 256 B, budget now full
        // a third sequence's reserve must evict the LRU (a_seq), not fail
        let c_seq = p.open_seq(1, 1, 8);
        assert!(c_seq.reserve(4));
        assert!(!a_seq.valid(), "LRU sequence evicted to make room");
        assert!(b_seq.valid());
        assert_eq!(a.used(), 512);
    }

    #[test]
    fn set_kv_budget_shrink_evicts_lru_sequences() {
        let (p, a) = pool(None, None);
        let old = p.open_seq(1, 1, 8); // block = 256 B
        let newer = p.open_seq(1, 1, 8);
        assert!(old.reserve(4));
        assert!(newer.reserve(4));
        assert_eq!(p.used_bytes(), 512);
        // cap 256: LRU sequence evicted, newer survives intact
        let freed = p.set_kv_budget(Some(256));
        assert_eq!(freed, 256);
        assert_eq!(p.kv_budget(), Some(256));
        assert!(!old.valid());
        assert!(newer.valid());
        assert_eq!(a.used(), 256);
        assert_eq!(p.stats().evicted_blocks, 1);
        // the new cap is live: the survivor cannot grow past it
        assert!(!newer.reserve(5));
        // grow re-opens headroom without touching anything
        assert_eq!(p.set_kv_budget(Some(1024)), 0);
        assert!(newer.reserve(5));
        assert_eq!(p.used_bytes(), 512);
    }

    #[test]
    fn invalidate_frees_but_keeps_tombstone() {
        let (p, a) = pool(None, None);
        let seq = p.open_seq(1, 1, 8);
        assert!(seq.reserve(4));
        assert!(a.used() > 0);
        seq.invalidate();
        assert_eq!(a.used(), 0);
        assert!(!seq.valid());
        assert_eq!(p.stats().sequences, 1, "tombstone remains until drop");
        drop(seq);
        assert_eq!(p.stats().sequences, 0);
    }

    #[test]
    fn failed_pass_never_reads_uncommitted_prefix() {
        let (p, _a) = pool(None, None);
        let seq = p.open_seq(1, 1, 4);
        assert!(seq.reserve(1));
        seq.write_token(0, 0, &[1.0; 4], &[2.0; 4]);
        // no set_tokens: the write is invisible
        assert_eq!(seq.tokens(), 0);
        let (dk, _dv) = seq.dense_kv(0, 2).unwrap();
        assert_eq!(dk, vec![0.0; 8]);
    }

    // ---- prefix sharing -------------------------------------------------

    /// Prime a 1-layer sequence with a deterministic 4-token prefix and
    /// commit it (seals the block).
    fn primed(p: &KvPool, tag: f32) -> KvSeq {
        let seq = p.open_seq(1, 1, 8);
        assert!(seq.reserve(4));
        let k: Vec<f32> = (0..32).map(|i| tag + i as f32).collect();
        let v: Vec<f32> = (0..32).map(|i| tag + 100.0 + i as f32).collect();
        seq.write_prefix(0, 4, &k, &v);
        seq.set_tokens(4);
        seq
    }

    #[test]
    fn identical_prefixes_dedup_to_one_charge() {
        let (p, a) = pool(Some(100_000), None);
        let s1 = primed(&p, 1.0);
        assert_eq!(a.used(), 256);
        let s2 = primed(&p, 1.0); // same content -> dedup at commit
        assert_eq!(a.used(), 256, "shared block charged once");
        assert_eq!(p.stats().shared_blocks, 1);
        assert_eq!(p.stats().shared_total, 1);
        assert_eq!(p.stats().dedup_bytes, 256);
        assert_eq!(p.stats().pool_blocks, 1);
        // both read the same content
        assert_eq!(s1.dense_kv(0, 4).unwrap(), s2.dense_kv(0, 4).unwrap());
        // different content never merges
        let s3 = primed(&p, 9.0);
        assert_eq!(a.used(), 512);
        drop(s3);
        // refcounts: first drop keeps the block, last drop frees it
        drop(s1);
        assert_eq!(a.used(), 256);
        assert!(s2.valid());
        assert_eq!(s2.dense_kv(0, 4).unwrap().0[0], 1.0);
        drop(s2);
        assert_eq!(a.used(), 0);
        assert_eq!(p.stats().pool_blocks, 0);
    }

    #[test]
    fn fork_shares_sealed_prefix_and_cow_diverges() {
        let (p, a) = pool(Some(100_000), None);
        let parent = primed(&p, 2.0);
        assert_eq!(a.used(), 256);
        let child = parent.fork().expect("sealed prefix forks");
        assert_eq!(child.tokens(), 4);
        assert_eq!(a.used(), 256, "fork charges nothing");
        assert_eq!(p.stats().shared_blocks, 1);
        assert_eq!(child.dense_kv(0, 4).unwrap(), parent.dense_kv(0, 4).unwrap());
        // child writes into the shared region -> COW, one extra block
        child.write_token(0, 0, &[77.0; 8], &[78.0; 8]);
        child.set_tokens(4);
        assert_eq!(a.used(), 512, "divergence pays for its own copy");
        assert_eq!(child.dense_kv(0, 4).unwrap().0[0], 77.0);
        assert_eq!(parent.dense_kv(0, 4).unwrap().0[0], 2.0, "parent untouched");
        drop(child);
        assert_eq!(a.used(), 256);
        drop(parent);
        assert_eq!(a.used(), 0);
    }

    #[test]
    fn refcount_aware_eviction_prefers_freeing_victims() {
        let (p, a) = pool(Some(100_000), None);
        // oldest: shares its only block with `peer` (evicting it frees 0)
        let oldest = primed(&p, 3.0);
        let peer = primed(&p, 3.0);
        // newest: private block (evicting it frees 256)
        let newest = primed(&p, 4.0);
        assert_eq!(a.used(), 512);
        // force the accountant full so evict_for must reclaim 256
        assert!(a.try_acquire(100_000 - 512));
        let freed = p.evict_for(256);
        assert_eq!(freed, 256, "the freeing victim was chosen");
        assert!(!newest.valid(), "private-block holder evicted despite being newest");
        assert!(oldest.valid() && peer.valid(), "all-shared sequences spared");
        a.free(100_000 - 512);
        drop(oldest);
        drop(peer);
        drop(newest);
        assert_eq!(a.used(), 0);
    }

    #[test]
    fn shared_block_survives_peer_eviction_and_recompute_rejoins() {
        let (p, a) = pool(Some(100_000), None);
        let s1 = primed(&p, 5.0);
        let s2 = primed(&p, 5.0);
        assert_eq!(p.stats().shared_blocks, 1);
        // evict s1 wholesale (elastic shrink to 0 headroom)
        s1.invalidate();
        assert!(!s1.valid());
        assert!(s2.valid(), "peer keeps the shared block");
        assert_eq!(a.used(), 256);
        assert_eq!(s2.dense_kv(0, 4).unwrap().0[0], 5.0);
        // s1 recomputes its prefix and dedups right back onto the block
        drop(s1);
        let s3 = primed(&p, 5.0);
        assert_eq!(a.used(), 256);
        assert_eq!(p.stats().shared_blocks, 1);
        drop(s2);
        drop(s3);
        assert_eq!(a.used(), 0);
    }
}
