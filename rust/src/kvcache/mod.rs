//! Paged KV-cache pool: memory-accounted attention state for incremental
//! decode.
//!
//! The paper's decode loop re-runs the full growing prefix for every
//! generated token; TPI-LLM (arXiv:2410.00531) and EdgeInfinite
//! (arXiv:2503.22196) both observe that on edge devices the KV cache is
//! the dominant *dynamic* memory consumer, so attention state must live
//! under the same budget as the pipeline's weights — not in an
//! unaccounted side buffer.  This module is that budget citizen:
//!
//! * a [`KvPool`] holds the cached K/V tensors for one session's
//!   in-flight sequences, allocated in **blocks** of
//!   [`KvPool::block_tokens`] tokens per layer.  Every block is charged
//!   against the shared [`MemoryAccountant`] (the same one the Loading
//!   Agents admit weights through) and additionally capped by the pool's
//!   own `kv_budget` — the per-lane allocation a
//!   [`crate::server::Router`] grants so one model's long generations
//!   cannot starve another model's weights or KV;
//! * a [`KvSeq`] is one sequence's RAII handle: dropping it (request
//!   completion or rejection) returns every block to the budget;
//! * under `S^stop` pressure the pool is an eviction target of the
//!   [`crate::pipeload::gate::OrderedGate`], alongside pinned hot
//!   layers: [`KvPool::evict_for`] reclaims whole sequences LRU-first.
//!   An evicted sequence is marked invalid, **not** an error — the decode
//!   loop falls back to a full-prefix recompute for that sequence, so
//!   correctness never depends on cache residency.
//!
//! Allocation never blocks: block grants use
//! [`MemoryAccountant::try_acquire`] (after trying to evict *other*
//! sequences), because the grab happens on the inference thread in the
//! middle of a pass — parking there would deadlock the pipeline that is
//! supposed to free the memory.  A failed grant degrades to uncached
//! decode, it never stalls.
//!
//! K/V data is stored token-major (`[token][batch][hidden]` per layer) so
//! appending one decoded token is a plain extend;
//! [`KvPool::dense_kv`] re-packs a layer into the `[batch, seq, hidden]`
//! buffers the `*_inc` HLO entries take, zero-filling past the cached
//! prefix (the entries mask attention at `pos`, so the padding is inert).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::memory::MemoryAccountant;

/// Default tokens per block (allocation granularity).  Small enough that
/// tiny test profiles (`max_seq` 16) exercise multi-block sequences.
pub const DEFAULT_BLOCK_TOKENS: usize = 8;

/// Pool counters (surfaced through `RunReport` / `ServeSummary` /
/// `serve --json`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvPoolStats {
    /// blocks ever granted
    pub allocated_blocks: u64,
    /// blocks reclaimed under `S^stop` pressure (gate eviction)
    pub evicted_blocks: u64,
    /// bytes currently accounted by the pool
    pub pool_bytes: u64,
    /// blocks currently held
    pub pool_blocks: u64,
    /// sequences currently registered (valid or evicted-but-open)
    pub sequences: usize,
}

#[derive(Debug)]
struct SeqState {
    /// per-layer K (and V) data, token-major [token][batch][hidden]
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    batch: usize,
    hidden: usize,
    /// cached prefix length in tokens (positions `0..tokens` are valid)
    tokens: usize,
    /// reserved capacity in tokens (grows in whole blocks)
    capacity: usize,
    /// bytes currently accounted for this sequence
    bytes: u64,
    /// blocks currently held by this sequence
    blocks: u64,
    /// LRU clock of the last reserve/advance (eviction victim = smallest)
    last_use: u64,
    /// cleared by eviction: data is gone, owner must recompute
    valid: bool,
}

impl SeqState {
    fn layers(&self) -> usize {
        self.k.len()
    }
}

#[derive(Debug, Default)]
struct PoolState {
    seqs: HashMap<u64, SeqState>,
    next_id: u64,
    clock: u64,
    used: u64,
    blocks: u64,
    allocated_blocks: u64,
    evicted_blocks: u64,
    /// pool-level byte cap (the lane's KV allocation); `None` = only the
    /// accountant's budget constrains the pool.  Mutable at run time —
    /// elastic budget steps rebalance it via [`KvPool::set_kv_budget`].
    kv_budget: Option<u64>,
}

impl PoolState {
    /// Drop one sequence's storage and return its (bytes, blocks), without
    /// removing the entry (eviction keeps the tombstone so the owner can
    /// observe the invalidation; release removes it entirely).
    fn strip(seq: &mut SeqState) -> (u64, u64) {
        let freed = (seq.bytes, seq.blocks);
        seq.k = Vec::new();
        seq.v = Vec::new();
        seq.tokens = 0;
        seq.capacity = 0;
        seq.bytes = 0;
        seq.blocks = 0;
        seq.valid = false;
        freed
    }
}

/// Shared paged KV pool; clone freely (Arc inside).  One per session.
#[derive(Debug, Clone)]
pub struct KvPool {
    accountant: MemoryAccountant,
    block_tokens: usize,
    inner: Arc<Mutex<PoolState>>,
}

impl KvPool {
    pub fn new(accountant: MemoryAccountant, kv_budget: Option<u64>) -> KvPool {
        KvPool::with_block_tokens(accountant, kv_budget, DEFAULT_BLOCK_TOKENS)
    }

    pub fn with_block_tokens(
        accountant: MemoryAccountant,
        kv_budget: Option<u64>,
        block_tokens: usize,
    ) -> KvPool {
        KvPool {
            accountant,
            block_tokens: block_tokens.max(1),
            inner: Arc::new(Mutex::new(PoolState { kv_budget, ..PoolState::default() })),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn kv_budget(&self) -> Option<u64> {
        self.inner.lock().unwrap().kv_budget
    }

    /// Retarget the pool cap (elastic budget step).  Shrinking below the
    /// currently held bytes evicts whole sequences LRU-first until the pool
    /// fits the new cap (their owners fall back to full-prefix recompute —
    /// degraded, never wrong); growing widens future reserve headroom.
    /// Returns bytes freed.
    pub fn set_kv_budget(&self, new_budget: Option<u64>) -> u64 {
        let mut freed = 0u64;
        loop {
            let victim = {
                let mut s = self.inner.lock().unwrap();
                s.kv_budget = new_budget;
                let Some(cap) = new_budget else { return freed };
                if s.used <= cap {
                    return freed;
                }
                s.seqs
                    .iter()
                    .filter(|(_, q)| q.valid && q.bytes > 0)
                    .min_by_key(|(_, q)| q.last_use)
                    .map(|(id, _)| *id)
            };
            let Some(vid) = victim else { return freed };
            let mut s = self.inner.lock().unwrap();
            let Some(seq) = s.seqs.get_mut(&vid) else { continue };
            let (b, blocks) = PoolState::strip(seq);
            s.used -= b;
            s.blocks -= blocks;
            s.evicted_blocks += blocks;
            drop(s);
            if b > 0 {
                self.accountant.free(b);
            }
            freed += b;
        }
    }

    /// Bytes of one block: `block_tokens` positions of K **and** V for one
    /// layer at the given (batch, hidden).
    fn block_bytes(&self, batch: usize, hidden: usize) -> u64 {
        (self.block_tokens * batch * hidden * 4 * 2) as u64
    }

    /// Register a new sequence (no memory is granted yet); returns its
    /// RAII handle.  `layers` is the number of body layers caching K/V.
    pub fn open_seq(&self, layers: usize, batch: usize, hidden: usize) -> KvSeq {
        let mut s = self.inner.lock().unwrap();
        let id = s.next_id;
        s.next_id += 1;
        s.clock += 1;
        let clock = s.clock;
        s.seqs.insert(
            id,
            SeqState {
                k: vec![Vec::new(); layers],
                v: vec![Vec::new(); layers],
                batch,
                hidden,
                tokens: 0,
                capacity: 0,
                bytes: 0,
                blocks: 0,
                last_use: clock,
                valid: true,
            },
        );
        KvSeq { pool: self.clone(), id }
    }

    /// Grow a sequence's reserved capacity to at least `tokens` positions.
    /// Grants whole blocks across every layer, charged to the accountant
    /// (non-blocking) and the pool budget.  On budget pressure it first
    /// evicts *other* sequences LRU-first.  `false` = could not reserve;
    /// the sequence stays as it was (caller decodes uncached).
    fn reserve(&self, id: u64, tokens: usize) -> bool {
        let (want, granted_blocks, new_capacity) = {
            let mut s = self.inner.lock().unwrap();
            s.clock += 1;
            let clock = s.clock;
            let Some(seq) = s.seqs.get_mut(&id) else { return false };
            if !seq.valid {
                return false;
            }
            seq.last_use = clock;
            if tokens <= seq.capacity {
                return true;
            }
            let new_capacity = tokens.div_ceil(self.block_tokens) * self.block_tokens;
            let need_blocks = (new_capacity - seq.capacity) / self.block_tokens * seq.layers();
            let per_block = self.block_bytes(seq.batch, seq.hidden);
            let want = need_blocks as u64 * per_block;
            if let Some(cap) = s.kv_budget {
                if s.used + want > cap {
                    return false;
                }
            }
            (want, need_blocks as u64, new_capacity)
        };
        // Take the grant outside the pool lock; under pressure, evict other
        // sequences first (never this one), then retry once.  Never block:
        // this runs on the inference thread mid-pass.
        if !self.accountant.try_acquire(want) {
            self.evict_lru_except(Some(id), want);
            if !self.accountant.try_acquire(want) {
                return false;
            }
        }
        let mut s = self.inner.lock().unwrap();
        let ok = s.seqs.get(&id).map(|seq| seq.valid).unwrap_or(false);
        if !ok {
            // evicted/released between locks: hand the grant straight back
            drop(s);
            self.accountant.free(want);
            return false;
        }
        let seq = s.seqs.get_mut(&id).unwrap();
        seq.capacity = new_capacity;
        seq.bytes += want;
        seq.blocks += granted_blocks;
        let cap_elems = new_capacity * seq.batch * seq.hidden;
        for l in 0..seq.layers() {
            seq.k[l].resize(cap_elems, 0.0);
            seq.v[l].resize(cap_elems, 0.0);
        }
        s.used += want;
        s.blocks += granted_blocks;
        s.allocated_blocks += granted_blocks;
        true
    }

    /// Write one token's K/V rows for one layer at position `pos`
    /// (token-major rows: `batch * hidden` values each).  Silently ignored
    /// if the sequence was evicted mid-pass — the pass still completes,
    /// only the cache write is lost.
    fn write_token(&self, id: u64, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let mut s = self.inner.lock().unwrap();
        let Some(seq) = s.seqs.get_mut(&id) else { return };
        if !seq.valid || pos >= seq.capacity || layer >= seq.layers() {
            return;
        }
        let row = seq.batch * seq.hidden;
        debug_assert_eq!(k.len(), row);
        debug_assert_eq!(v.len(), row);
        seq.k[layer][pos * row..(pos + 1) * row].copy_from_slice(k);
        seq.v[layer][pos * row..(pos + 1) * row].copy_from_slice(v);
    }

    /// Bulk-write positions `0..tokens` of one layer (the full-prefix
    /// prime).  `k`/`v` are token-major `[tokens][batch][hidden]`.
    fn write_prefix(&self, id: u64, layer: usize, tokens: usize, k: &[f32], v: &[f32]) {
        let mut s = self.inner.lock().unwrap();
        let Some(seq) = s.seqs.get_mut(&id) else { return };
        if !seq.valid || tokens > seq.capacity || layer >= seq.layers() {
            return;
        }
        let n = tokens * seq.batch * seq.hidden;
        debug_assert_eq!(k.len(), n);
        debug_assert_eq!(v.len(), n);
        seq.k[layer][..n].copy_from_slice(k);
        seq.v[layer][..n].copy_from_slice(v);
    }

    /// Commit the cached prefix length (only after a pass fully succeeds,
    /// so a failed pass can never leave a half-written prefix readable).
    fn set_tokens(&self, id: u64, tokens: usize) {
        let mut s = self.inner.lock().unwrap();
        s.clock += 1;
        let clock = s.clock;
        if let Some(seq) = s.seqs.get_mut(&id) {
            if seq.valid && tokens <= seq.capacity {
                seq.tokens = tokens;
                seq.last_use = clock;
            }
        }
    }

    /// Re-pack one layer's cached K/V into dense `[batch, seq_len, hidden]`
    /// buffers (zero past the prefix), for upload to an `*_inc` entry.
    /// `None` if the sequence is gone or was evicted.
    fn dense_kv(&self, id: u64, layer: usize, seq_len: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        let s = self.inner.lock().unwrap();
        let seq = s.seqs.get(&id)?;
        if !seq.valid || layer >= seq.layers() {
            return None;
        }
        let (b, h) = (seq.batch, seq.hidden);
        let t = seq.tokens.min(seq_len);
        let mut dk = vec![0.0f32; b * seq_len * h];
        let mut dv = vec![0.0f32; b * seq_len * h];
        for tok in 0..t {
            for row in 0..b {
                let src = tok * b * h + row * h;
                let dst = row * seq_len * h + tok * h;
                dk[dst..dst + h].copy_from_slice(&seq.k[layer][src..src + h]);
                dv[dst..dst + h].copy_from_slice(&seq.v[layer][src..src + h]);
            }
        }
        Some((dk, dv))
    }

    fn seq_tokens(&self, id: u64) -> Option<usize> {
        let s = self.inner.lock().unwrap();
        s.seqs.get(&id).filter(|q| q.valid).map(|q| q.tokens)
    }

    fn seq_valid(&self, id: u64) -> bool {
        let s = self.inner.lock().unwrap();
        s.seqs.get(&id).map(|q| q.valid).unwrap_or(false)
    }

    /// Drop a sequence's storage without removing it (the owner sees
    /// `valid() == false` and recomputes).  Used on pass failure.
    fn invalidate(&self, id: u64) {
        let mut s = self.inner.lock().unwrap();
        let Some(seq) = s.seqs.get_mut(&id) else { return };
        let (bytes, blocks) = PoolState::strip(seq);
        s.used -= bytes;
        s.blocks -= blocks;
        drop(s);
        if bytes > 0 {
            self.accountant.free(bytes);
        }
    }

    /// Remove a sequence entirely, returning its blocks to the budget
    /// (request completion/rejection; `KvSeq::drop` calls this).
    fn release(&self, id: u64) {
        let mut s = self.inner.lock().unwrap();
        let Some(mut seq) = s.seqs.remove(&id) else { return };
        let (bytes, blocks) = PoolState::strip(&mut seq);
        s.used -= bytes;
        s.blocks -= blocks;
        drop(s);
        if bytes > 0 {
            self.accountant.free(bytes);
        }
    }

    /// Evict LRU sequences (optionally sparing one) until either `bytes`
    /// fit the accountant's budget or nothing is left.  Returns bytes
    /// freed.  Evicted sequences keep a tombstone entry so their owners
    /// observe the invalidation and fall back to full-prefix recompute.
    fn evict_lru_except(&self, spare: Option<u64>, bytes: u64) -> u64 {
        let mut freed = 0u64;
        loop {
            if !self.accountant.would_block(bytes) {
                break;
            }
            let mut s = self.inner.lock().unwrap();
            let victim = s
                .seqs
                .iter()
                .filter(|(id, q)| q.valid && q.bytes > 0 && Some(**id) != spare)
                .min_by_key(|(_, q)| q.last_use)
                .map(|(id, _)| *id);
            let Some(vid) = victim else { break };
            let seq = s.seqs.get_mut(&vid).unwrap();
            let (b, blocks) = PoolState::strip(seq);
            s.used -= b;
            s.blocks -= blocks;
            s.evicted_blocks += blocks;
            drop(s);
            self.accountant.free(b);
            freed += b;
        }
        freed
    }

    /// Strip every sequence's storage and return all blocks to the
    /// accountant, keeping tombstones so owners observe the invalidation
    /// (failed-pass recovery: the session must release exactly its own
    /// bytes without guessing which sequences were mid-flight).  Returns
    /// bytes freed.
    pub fn invalidate_all(&self) -> u64 {
        let mut s = self.inner.lock().unwrap();
        let mut freed = 0u64;
        let ids: Vec<u64> = s.seqs.keys().copied().collect();
        for id in ids {
            let seq = s.seqs.get_mut(&id).unwrap();
            let (bytes, blocks) = PoolState::strip(seq);
            s.used -= bytes;
            s.blocks -= blocks;
            freed += bytes;
        }
        drop(s);
        if freed > 0 {
            self.accountant.free(freed);
        }
        freed
    }

    /// `S^stop` pressure valve (gate eviction target, like
    /// [`crate::pipeload::cache::LayerCache::evict_for`]): evict whole
    /// sequences LRU-first until `bytes` fit this pool's accountant —
    /// which is the same shared accountant the gate admits against, by
    /// construction.  Returns bytes freed.
    pub fn evict_for(&self, bytes: u64) -> u64 {
        self.evict_lru_except(None, bytes)
    }

    /// Bytes currently accounted by the pool.
    pub fn used_bytes(&self) -> u64 {
        self.inner.lock().unwrap().used
    }

    pub fn stats(&self) -> KvPoolStats {
        let s = self.inner.lock().unwrap();
        KvPoolStats {
            allocated_blocks: s.allocated_blocks,
            evicted_blocks: s.evicted_blocks,
            pool_bytes: s.used,
            pool_blocks: s.blocks,
            sequences: s.seqs.len(),
        }
    }
}

/// RAII handle to one sequence's cached K/V.  Dropping it frees every
/// block back to the budget — the per-request lifecycle the Router relies
/// on (blocks are gone when the ticket resolves, served or rejected).
#[derive(Debug)]
pub struct KvSeq {
    pool: KvPool,
    id: u64,
}

impl KvSeq {
    /// Cached prefix length (`None`/0 once evicted).
    pub fn tokens(&self) -> usize {
        self.pool.seq_tokens(self.id).unwrap_or(0)
    }

    /// False once the gate (or a failed pass) reclaimed this sequence.
    pub fn valid(&self) -> bool {
        self.pool.seq_valid(self.id)
    }

    /// Ensure capacity for a prefix of `tokens` positions (block-granular,
    /// non-blocking).  `false` = budget pressure; decode uncached.
    pub fn reserve(&self, tokens: usize) -> bool {
        self.pool.reserve(self.id, tokens)
    }

    pub fn write_token(&self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.pool.write_token(self.id, layer, pos, k, v);
    }

    pub fn write_prefix(&self, layer: usize, tokens: usize, k: &[f32], v: &[f32]) {
        self.pool.write_prefix(self.id, layer, tokens, k, v);
    }

    pub fn set_tokens(&self, tokens: usize) {
        self.pool.set_tokens(self.id, tokens);
    }

    pub fn dense_kv(&self, layer: usize, seq_len: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        self.pool.dense_kv(self.id, layer, seq_len)
    }

    /// Drop the cached data (kept registered, marked invalid).
    pub fn invalidate(&self) {
        self.pool.invalidate(self.id);
    }
}

impl Drop for KvSeq {
    fn drop(&mut self) {
        self.pool.release(self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(budget: Option<u64>, kv_budget: Option<u64>) -> (KvPool, MemoryAccountant) {
        let a = MemoryAccountant::new(budget);
        (KvPool::with_block_tokens(a.clone(), kv_budget, 4), a)
    }

    #[test]
    fn reserve_charges_blocks_and_release_refunds() {
        let (p, a) = pool(Some(100_000), None);
        let seq = p.open_seq(2, 1, 8); // 2 layers, B=1, H=8
        // block = 4 tokens * 1 * 8 * 4 B * 2(K+V) = 256 B; 2 layers = 512 B
        assert!(seq.reserve(1));
        assert_eq!(a.used(), 512);
        assert_eq!(p.used_bytes(), 512);
        assert_eq!(p.stats().pool_blocks, 2);
        // within the same block: no new charge
        assert!(seq.reserve(4));
        assert_eq!(a.used(), 512);
        // fifth token needs a second block row across both layers
        assert!(seq.reserve(5));
        assert_eq!(a.used(), 1024);
        assert_eq!(p.stats().allocated_blocks, 4);
        drop(seq);
        assert_eq!(a.used(), 0);
        assert_eq!(p.stats().sequences, 0);
    }

    #[test]
    fn kv_budget_caps_pool_even_with_accountant_headroom() {
        let (p, a) = pool(Some(1_000_000), Some(600));
        let seq = p.open_seq(2, 1, 8); // 512 B per block row
        assert!(seq.reserve(4));
        assert!(!seq.reserve(5), "second block row would exceed the 600 B kv budget");
        assert_eq!(a.used(), 512);
        // the failed reserve must not have leaked anything
        assert_eq!(p.used_bytes(), 512);
        assert!(seq.valid());
        assert_eq!(seq.tokens(), 0);
    }

    #[test]
    fn write_commit_dense_roundtrip() {
        let (p, _a) = pool(None, None);
        let seq = p.open_seq(1, 2, 4); // 1 layer, B=2, H=4
        assert!(seq.reserve(2));
        // prime position 0 for both rows, then append position 1
        let k0: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let v0: Vec<f32> = (0..8).map(|i| 10.0 + i as f32).collect();
        seq.write_prefix(0, 1, &k0, &v0);
        seq.set_tokens(1);
        let k1: Vec<f32> = (0..8).map(|i| 100.0 + i as f32).collect();
        let v1: Vec<f32> = (0..8).map(|i| 110.0 + i as f32).collect();
        seq.write_token(0, 1, &k1, &v1);
        seq.set_tokens(2);
        assert_eq!(seq.tokens(), 2);
        let (dk, dv) = seq.dense_kv(0, 3).unwrap(); // dense [2, 3, 4]
        // row 0: tokens 0,1 then zero padding
        assert_eq!(&dk[0..4], &k0[0..4]);
        assert_eq!(&dk[4..8], &k1[0..4]);
        assert_eq!(&dk[8..12], &[0.0; 4]);
        // row 1 lives at stride seq_len*H = 12
        assert_eq!(&dk[12..16], &k0[4..8]);
        assert_eq!(&dv[16..20], &v1[4..8]);
    }

    #[test]
    fn eviction_invalidates_lru_sequence_and_frees_budget() {
        let (p, a) = pool(Some(1100), None);
        let old = p.open_seq(1, 1, 8); // block = 256 B
        let newer = p.open_seq(1, 1, 8);
        assert!(old.reserve(4));
        assert!(newer.reserve(4));
        assert_eq!(a.used(), 512);
        // an outside admission of 800 B needs 212 B reclaimed -> evict `old`
        let freed = p.evict_for(800);
        assert_eq!(freed, 256);
        assert!(!old.valid());
        assert!(newer.valid());
        assert_eq!(p.stats().evicted_blocks, 1);
        assert_eq!(a.used(), 256);
        // evicted sequence degrades gracefully
        assert_eq!(old.tokens(), 0);
        assert!(old.dense_kv(0, 4).is_none());
        assert!(!old.reserve(1));
        old.write_token(0, 0, &[0.0; 8], &[0.0; 8]); // ignored, no panic
    }

    #[test]
    fn reserve_evicts_other_sequences_before_failing() {
        let (p, a) = pool(Some(512), None);
        let a_seq = p.open_seq(1, 1, 8);
        assert!(a_seq.reserve(4)); // 256 B
        let b_seq = p.open_seq(1, 1, 8);
        assert!(b_seq.reserve(4)); // 256 B, budget now full
        // a third sequence's reserve must evict the LRU (a_seq), not fail
        let c_seq = p.open_seq(1, 1, 8);
        assert!(c_seq.reserve(4));
        assert!(!a_seq.valid(), "LRU sequence evicted to make room");
        assert!(b_seq.valid());
        assert_eq!(a.used(), 512);
    }

    #[test]
    fn set_kv_budget_shrink_evicts_lru_sequences() {
        let (p, a) = pool(None, None);
        let old = p.open_seq(1, 1, 8); // block = 256 B
        let newer = p.open_seq(1, 1, 8);
        assert!(old.reserve(4));
        assert!(newer.reserve(4));
        assert_eq!(p.used_bytes(), 512);
        // cap 256: LRU sequence evicted, newer survives intact
        let freed = p.set_kv_budget(Some(256));
        assert_eq!(freed, 256);
        assert_eq!(p.kv_budget(), Some(256));
        assert!(!old.valid());
        assert!(newer.valid());
        assert_eq!(a.used(), 256);
        assert_eq!(p.stats().evicted_blocks, 1);
        // the new cap is live: the survivor cannot grow past it
        assert!(!newer.reserve(5));
        // grow re-opens headroom without touching anything
        assert_eq!(p.set_kv_budget(Some(1024)), 0);
        assert!(newer.reserve(5));
        assert_eq!(p.used_bytes(), 512);
    }

    #[test]
    fn invalidate_frees_but_keeps_tombstone() {
        let (p, a) = pool(None, None);
        let seq = p.open_seq(1, 1, 8);
        assert!(seq.reserve(4));
        assert!(a.used() > 0);
        seq.invalidate();
        assert_eq!(a.used(), 0);
        assert!(!seq.valid());
        assert_eq!(p.stats().sequences, 1, "tombstone remains until drop");
        drop(seq);
        assert_eq!(p.stats().sequences, 0);
    }

    #[test]
    fn failed_pass_never_reads_uncommitted_prefix() {
        let (p, _a) = pool(None, None);
        let seq = p.open_seq(1, 1, 4);
        assert!(seq.reserve(1));
        seq.write_token(0, 0, &[1.0; 4], &[2.0; 4]);
        // no set_tokens: the write is invisible
        assert_eq!(seq.tokens(), 0);
        let (dk, _dv) = seq.dense_kv(0, 2).unwrap();
        assert_eq!(dk, vec![0.0; 8]);
    }
}
