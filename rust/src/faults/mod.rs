//! Deterministic fault-injection plane + the recovery primitives it proves.
//!
//! Edge deployments fail constantly — slow or flaky storage, memory
//! pressure spikes, worker churn, clients vanishing mid-request — and every
//! recovery path that is only exercised by accident is a recovery path that
//! does not work.  This module makes failure a first-class, *reproducible*
//! input:
//!
//! * [`FaultPlan`] — a seeded, declarative schedule of faults (`--fault-plan
//!   <file|spec>`): JSON steps like `{"at_pass": 3, "lane": 1, "kind":
//!   "disk_error", "count": 2}` or the compact inline spec
//!   `seed=7;disk_error@3x2:1;agent_panic@5`.
//! * [`FaultInjector`] — the runtime half, threaded through the natural
//!   seams (disk opens, loader agents, lane executors, accountant
//!   admissions, TCP connections).  Cloning is cheap; a disabled injector
//!   costs one branch per probe.  Fired faults emit `fault_injected`
//!   telemetry instants tagged with the fault kind.
//! * [`FaultStats`] — shared atomic counters (`faults_injected`,
//!   `load_retries`, `passes_timed_out`, `lane_restarts`, `requeued`) that
//!   flow through `RunReport` / `RouterSummary` / `ServeSummary` /
//!   Prometheus.
//! * [`RetryPolicy`] — bounded retry with deterministic jittered backoff
//!   for transient load failures (same seed → same schedule).
//! * [`Watchdog`] — a per-pass timeout: if a pass hangs past its deadline
//!   the watchdog runs a caller-supplied quiesce action (in practice
//!   `OrderedGate::shutdown`, which unblocks every parked admission as an
//!   error and drives the existing failed-pass drain).
//!
//! Determinism contract: fault firing depends only on the plan, the pass
//! clock, and call order — never on wall time — so a seeded chaos run is
//! replayable and the chaos soak can assert bit-identical tokens for every
//! request that survives.
#![warn(clippy::unwrap_used)]

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::telemetry::{worker, EvArgs, Telemetry};
use crate::util::json::Value;

/// What to break.  Each kind maps to one injection seam.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `Disk::open` fails with a transient I/O error (retryable).
    DiskError,
    /// `Disk::open` sleeps `ms` first (a stuck medium; trips the watchdog).
    DiskSlow,
    /// A loading agent panics at task start (contained by `catch_unwind`).
    AgentPanic,
    /// A lane executor dies mid-serve (contained by the lane supervisor).
    LaneDeath,
    /// A memory-accountant admission is transiently refused once.
    AcquireFail,
    /// The TCP front-end drops the client connection.
    ConnDrop,
}

impl FaultKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultKind::DiskError => "disk_error",
            FaultKind::DiskSlow => "disk_slow",
            FaultKind::AgentPanic => "agent_panic",
            FaultKind::LaneDeath => "lane_death",
            FaultKind::AcquireFail => "acquire_fail",
            FaultKind::ConnDrop => "conn_drop",
        }
    }

    pub fn parse(s: &str) -> Result<FaultKind> {
        Ok(match s {
            "disk_error" => FaultKind::DiskError,
            "disk_slow" => FaultKind::DiskSlow,
            "agent_panic" => FaultKind::AgentPanic,
            "lane_death" => FaultKind::LaneDeath,
            "acquire_fail" => FaultKind::AcquireFail,
            "conn_drop" => FaultKind::ConnDrop,
            other => bail!(
                "unknown fault kind '{other}' (disk_error, disk_slow, agent_panic, \
                 lane_death, acquire_fail, conn_drop)"
            ),
        })
    }
}

/// One scheduled fault: fire `kind` up to `count` times once the global
/// pass clock reaches `at_pass`, optionally restricted to one lane.
#[derive(Debug, Clone)]
pub struct FaultStep {
    pub at_pass: u64,
    pub lane: Option<u32>,
    pub kind: FaultKind,
    pub count: u64,
    /// extra milliseconds for `disk_slow`
    pub ms: u64,
}

/// A declarative fault schedule; see the module docs for both syntaxes.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub steps: Vec<FaultStep>,
}

impl FaultPlan {
    /// Parse `--fault-plan`'s argument: a path to a JSON plan file, inline
    /// JSON (starts with `{`), or the compact spec
    /// `seed=N;kind@pass[xcount][:lane][+ms];...`.
    pub fn from_arg(arg: &str) -> Result<FaultPlan> {
        let arg = arg.trim();
        if arg.starts_with('{') {
            return FaultPlan::from_json(&Value::parse(arg).context("inline fault plan")?);
        }
        let path = std::path::Path::new(arg);
        if path.exists() {
            return FaultPlan::from_json(&Value::from_file(path)?);
        }
        FaultPlan::from_spec(arg)
    }

    /// `{"seed": 7, "steps": [{"at_pass":3,"kind":"disk_error","count":2,
    /// "lane":1,"ms":0}, ...]}` — `seed`, `count`, `lane`, `ms` optional.
    pub fn from_json(v: &Value) -> Result<FaultPlan> {
        let seed = match v.get("seed") {
            Some(s) => s.as_f64()? as u64,
            None => 0,
        };
        let mut steps = Vec::new();
        for (i, s) in v.req("steps")?.as_arr()?.iter().enumerate() {
            let ctx = || format!("fault step {i}");
            let kind = FaultKind::parse(s.req("kind").with_context(ctx)?.as_str()?)?;
            let at_pass = match s.get("at_pass") {
                Some(p) => p.as_f64()? as u64,
                None => 0,
            };
            let count = match s.get("count") {
                Some(c) => (c.as_f64()? as u64).max(1),
                None => 1,
            };
            let lane = match s.get("lane") {
                Some(Value::Null) | None => None,
                Some(l) => Some(l.as_f64()? as u32),
            };
            let ms = match s.get("ms") {
                Some(m) => m.as_f64()? as u64,
                None => 0,
            };
            steps.push(FaultStep { at_pass, lane, kind, count, ms });
        }
        Ok(FaultPlan { seed, steps })
    }

    /// Compact spec: `;`-separated items, each `seed=N` or
    /// `kind@pass[xcount][:lane][+ms]` — e.g.
    /// `seed=7;disk_error@3x2;disk_slow@2+50;lane_death@6:1`.
    pub fn from_spec(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for item in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            if let Some(seed) = item.strip_prefix("seed=") {
                plan.seed = seed.parse().with_context(|| format!("seed in '{item}'"))?;
                continue;
            }
            let (kind_s, rest) = item
                .split_once('@')
                .ok_or_else(|| anyhow!("fault spec item '{item}' needs kind@pass"))?;
            let kind = FaultKind::parse(kind_s)?;
            let mut rest = rest.to_string();
            let mut ms = 0u64;
            if let Some((head, ms_s)) = rest.split_once('+') {
                ms = ms_s.parse().with_context(|| format!("+ms in '{item}'"))?;
                rest = head.to_string();
            }
            let mut lane = None;
            if let Some((head, lane_s)) = rest.split_once(':') {
                lane = Some(lane_s.parse().with_context(|| format!(":lane in '{item}'"))?);
                rest = head.to_string();
            }
            let mut count = 1u64;
            if let Some((head, count_s)) = rest.split_once('x') {
                count = count_s.parse().with_context(|| format!("xcount in '{item}'"))?;
                rest = head.to_string();
            }
            let at_pass: u64 = rest.parse().with_context(|| format!("pass in '{item}'"))?;
            plan.steps.push(FaultStep { at_pass, lane, kind, count: count.max(1), ms });
        }
        if plan.steps.is_empty() {
            bail!("fault plan '{spec}' schedules no faults");
        }
        Ok(plan)
    }
}

/// Shared atomic fault/recovery counters; clone freely (Arc inside).
/// Always live — retries and restarts are counted even when no fault plan
/// is loaded (real disks fail too).
#[derive(Debug, Clone, Default)]
pub struct FaultStats {
    inner: Arc<StatsInner>,
}

#[derive(Debug, Default)]
struct StatsInner {
    faults_injected: AtomicU64,
    load_retries: AtomicU64,
    passes_timed_out: AtomicU64,
    lane_restarts: AtomicU64,
    requeued: AtomicU64,
}

/// One coherent read of [`FaultStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStatsSnapshot {
    pub faults_injected: u64,
    pub load_retries: u64,
    pub passes_timed_out: u64,
    pub lane_restarts: u64,
    pub requeued: u64,
}

impl FaultStats {
    pub fn note_injected(&self) {
        self.inner.faults_injected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_load_retry(&self) {
        self.inner.load_retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_pass_timeout(&self) {
        self.inner.passes_timed_out.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_lane_restart(&self) {
        self.inner.lane_restarts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_requeued(&self) {
        self.inner.requeued.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> FaultStatsSnapshot {
        FaultStatsSnapshot {
            faults_injected: self.inner.faults_injected.load(Ordering::Relaxed),
            load_retries: self.inner.load_retries.load(Ordering::Relaxed),
            passes_timed_out: self.inner.passes_timed_out.load(Ordering::Relaxed),
            lane_restarts: self.inner.lane_restarts.load(Ordering::Relaxed),
            requeued: self.inner.requeued.load(Ordering::Relaxed),
        }
    }
}

struct StepState {
    step: FaultStep,
    remaining: AtomicU64,
}

struct PlanInner {
    seed: u64,
    steps: Vec<StepState>,
    /// global pass clock; ticked by sessions at pass boundaries
    pass: AtomicU64,
    armed: AtomicBool,
    telemetry: Mutex<Telemetry>,
}

/// The runtime injector: probe sites call [`FaultInjector::fire`] and get
/// `true` when the plan says this site, on this lane, breaks *now*.
///
/// `off()` (and `Default`) build a disabled injector whose probes are one
/// `Option` branch — safe to leave on every hot path.  Counters
/// ([`FaultInjector::stats`]) are live either way.
#[derive(Clone, Default)]
pub struct FaultInjector {
    plan: Option<Arc<PlanInner>>,
    stats: FaultStats,
    lane: Option<u32>,
}

impl std::fmt::Debug for FaultInjector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.plan {
            None => write!(f, "FaultInjector(off)"),
            Some(p) => write!(
                f,
                "FaultInjector({} steps, pass {}, lane {:?})",
                p.steps.len(),
                p.pass.load(Ordering::Relaxed),
                self.lane
            ),
        }
    }
}

impl FaultInjector {
    /// No plan: every probe is false, counters still work.
    pub fn off() -> FaultInjector {
        FaultInjector::default()
    }

    pub fn new(plan: FaultPlan) -> FaultInjector {
        let steps = plan
            .steps
            .into_iter()
            .map(|step| StepState { remaining: AtomicU64::new(step.count), step })
            .collect();
        FaultInjector {
            plan: Some(Arc::new(PlanInner {
                seed: plan.seed,
                steps,
                pass: AtomicU64::new(0),
                armed: AtomicBool::new(true),
                telemetry: Mutex::new(Telemetry::off()),
            })),
            stats: FaultStats::default(),
            lane: None,
        }
    }

    /// The plan's seed (None when no plan is loaded) — consumers derive
    /// their deterministic jitter from it (e.g. [`RetryPolicy::seed`]).
    pub fn plan_seed(&self) -> Option<u64> {
        self.plan.as_ref().map(|p| p.seed)
    }

    /// Parse-and-build straight from the `--fault-plan` argument.
    pub fn from_arg(arg: &str) -> Result<FaultInjector> {
        Ok(FaultInjector::new(FaultPlan::from_arg(arg)?))
    }

    pub fn is_on(&self) -> bool {
        self.plan.is_some()
    }

    /// Tag a clone with the lane it probes for (mirrors
    /// `Telemetry::with_lane`); lane-scoped plan steps match against it.
    pub fn with_lane(&self, lane: u32) -> FaultInjector {
        FaultInjector { plan: self.plan.clone(), stats: self.stats.clone(), lane: Some(lane) }
    }

    /// Attach the telemetry bus fired faults report to.
    pub fn set_telemetry(&self, t: Telemetry) {
        if let Some(p) = &self.plan {
            *p.telemetry.lock().unwrap_or_else(std::sync::PoisonError::into_inner) = t;
        }
    }

    /// Advance the global pass clock (sessions call this per pass).
    pub fn tick_pass(&self) -> u64 {
        match &self.plan {
            Some(p) => p.pass.fetch_add(1, Ordering::Relaxed) + 1,
            None => 0,
        }
    }

    /// Stop all further firing (used by tests and terminal recovery).
    pub fn disarm(&self) {
        if let Some(p) = &self.plan {
            p.armed.store(false, Ordering::Relaxed);
        }
    }

    /// Should `kind` break at this probe?  Consumes one count on match.
    pub fn fire(&self, kind: FaultKind) -> bool {
        self.fire_ms(kind).is_some()
    }

    /// Like [`FaultInjector::fire`], returning the step's `ms` payload
    /// (the injected delay for `disk_slow`).
    pub fn fire_ms(&self, kind: FaultKind) -> Option<u64> {
        let p = self.plan.as_ref()?;
        if !p.armed.load(Ordering::Relaxed) {
            return None;
        }
        let pass = p.pass.load(Ordering::Relaxed);
        for st in &p.steps {
            if st.step.kind != kind || pass < st.step.at_pass {
                continue;
            }
            if let (Some(want), Some(have)) = (st.step.lane, self.lane) {
                if want != have {
                    continue;
                }
            } else if st.step.lane.is_some() && self.lane.is_none() {
                continue;
            }
            // consume one count; CAS loop so concurrent probes never
            // overfire a step
            let mut rem = st.remaining.load(Ordering::Relaxed);
            loop {
                if rem == 0 {
                    break;
                }
                match st.remaining.compare_exchange(
                    rem,
                    rem - 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        self.stats.note_injected();
                        let tel = p
                            .telemetry
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner)
                            .clone();
                        let tel = match self.lane {
                            Some(l) => tel.with_lane(l),
                            None => tel,
                        };
                        tel.instant(
                            "fault_injected",
                            worker::DRIVER,
                            EvArgs::pass(pass).with_reason(kind.as_str()),
                        );
                        return Some(st.step.ms);
                    }
                    Err(now) => rem = now,
                }
            }
        }
        None
    }

    pub fn stats(&self) -> &FaultStats {
        &self.stats
    }

    pub fn snapshot(&self) -> FaultStatsSnapshot {
        self.stats.snapshot()
    }
}

/// Bounded retry with deterministic jittered backoff.  `attempt` is
/// 1-based; the jitter is a pure function of `(seed, salt, attempt)` so a
/// seeded run replays the exact same schedule.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    pub max_retries: u32,
    pub base_backoff_ms: u64,
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_retries: 2, base_backoff_ms: 1, seed: 0 }
    }
}

/// splitmix64 — tiny, deterministic, good enough for jitter.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

impl RetryPolicy {
    /// Exponential base with deterministic jitter in `[0, base)`.
    pub fn backoff_ms(&self, salt: u64, attempt: u32) -> u64 {
        let base = self.base_backoff_ms.max(1);
        let exp = base.saturating_mul(1u64 << attempt.min(16));
        let jitter = splitmix64(self.seed ^ salt.rotate_left(17) ^ u64::from(attempt)) % base;
        exp + jitter
    }
}

// ---------------------------------------------------------------------------
// Pass watchdog
// ---------------------------------------------------------------------------

type WdAction = Box<dyn FnOnce() + Send>;

struct WdState {
    deadline: Option<Instant>,
    action: Option<WdAction>,
    gen: u64,
    fired: u64,
    quit: bool,
}

struct WdShared {
    state: Mutex<WdState>,
    cv: Condvar,
}

/// One persistent monitor thread; [`Watchdog::arm`] returns a guard that
/// disarms on drop.  If the deadline passes while armed, the action runs
/// exactly once on the monitor thread.
pub struct Watchdog {
    shared: Arc<WdShared>,
    monitor: Option<std::thread::JoinHandle<()>>,
}

impl Watchdog {
    pub fn new() -> Watchdog {
        let shared = Arc::new(WdShared {
            state: Mutex::new(WdState {
                deadline: None,
                action: None,
                gen: 0,
                fired: 0,
                quit: false,
            }),
            cv: Condvar::new(),
        });
        let s2 = shared.clone();
        let monitor = std::thread::Builder::new()
            .name("hermes-watchdog".into())
            .spawn(move || Watchdog::monitor(&s2))
            .ok();
        Watchdog { shared, monitor }
    }

    fn monitor(sh: &WdShared) {
        let mut st = sh.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        loop {
            if st.quit {
                return;
            }
            match st.deadline {
                None => {
                    st = sh
                        .cv
                        .wait(st)
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                }
                Some(dl) => {
                    let now = Instant::now();
                    if now < dl {
                        let (ns, _) = sh
                            .cv
                            .wait_timeout(st, dl - now)
                            .unwrap_or_else(std::sync::PoisonError::into_inner);
                        st = ns;
                        continue;
                    }
                    // expired while still armed: fire
                    let action = st.action.take();
                    st.deadline = None;
                    st.fired += 1;
                    drop(st);
                    if let Some(a) = action {
                        a();
                    }
                    st = sh.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                }
            }
        }
    }

    /// Arm for one pass.  Dropping the guard (pass finished) disarms; if
    /// the timeout elapses first, `action` runs on the monitor thread.
    pub fn arm(&self, timeout: Duration, action: impl FnOnce() + Send + 'static) -> WatchdogGuard {
        let mut st = self
            .shared
            .state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        st.gen += 1;
        st.deadline = Some(Instant::now() + timeout);
        st.action = Some(Box::new(action));
        let gen = st.gen;
        drop(st);
        self.shared.cv.notify_all();
        WatchdogGuard { shared: self.shared.clone(), gen }
    }

    /// How many times the watchdog has ever fired.
    pub fn fired(&self) -> u64 {
        self.shared
            .state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .fired
    }
}

impl Default for Watchdog {
    fn default() -> Watchdog {
        Watchdog::new()
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        {
            let mut st = self
                .shared
                .state
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            st.quit = true;
            st.deadline = None;
            st.action = None;
        }
        self.shared.cv.notify_all();
        if let Some(m) = self.monitor.take() {
            let _ = m.join();
        }
    }
}

/// Disarms its [`Watchdog`] on drop (if that arm is still the active one).
pub struct WatchdogGuard {
    shared: Arc<WdShared>,
    gen: u64,
}

impl WatchdogGuard {
    /// Did this arm's timeout fire before the pass completed?
    pub fn expired(&self) -> bool {
        let st = self
            .shared
            .state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        st.gen == self.gen && st.deadline.is_none() && st.action.is_none() && st.fired > 0
    }
}

impl Drop for WatchdogGuard {
    fn drop(&mut self) {
        let mut st = self
            .shared
            .state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        if st.gen == self.gen {
            st.deadline = None;
            st.action = None;
        }
        drop(st);
        self.shared.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn plan_from_json_and_spec_agree() {
        let j = FaultPlan::from_arg(
            r#"{"seed": 7, "steps": [
                {"at_pass": 3, "kind": "disk_error", "count": 2, "lane": 1},
                {"at_pass": 2, "kind": "disk_slow", "ms": 50},
                {"at_pass": 6, "kind": "lane_death"}
            ]}"#,
        )
        .expect("json plan");
        let s = FaultPlan::from_arg("seed=7;disk_error@3x2:1;disk_slow@2+50;lane_death@6")
            .expect("spec plan");
        assert_eq!(j.seed, s.seed);
        assert_eq!(j.steps.len(), s.steps.len());
        for (a, b) in j.steps.iter().zip(&s.steps) {
            assert_eq!(a.at_pass, b.at_pass);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.count, b.count);
            assert_eq!(a.lane, b.lane);
            assert_eq!(a.ms, b.ms);
        }
    }

    #[test]
    fn bad_plans_rejected() {
        assert!(FaultPlan::from_arg("seed=1").is_err(), "no steps");
        assert!(FaultPlan::from_arg("explode@1").is_err(), "unknown kind");
        assert!(FaultPlan::from_arg(r#"{"steps": [{"kind": "nope"}]}"#).is_err());
        assert!(FaultPlan::from_arg("disk_error").is_err(), "missing @pass");
    }

    #[test]
    fn fire_respects_pass_lane_and_count() {
        let inj = FaultInjector::from_arg("disk_error@2x2;lane_death@1:1").expect("plan");
        // pass clock at 0: nothing fires
        assert!(!inj.fire(FaultKind::DiskError));
        inj.tick_pass();
        inj.tick_pass();
        // lane steps need a lane-tagged probe
        assert!(!inj.fire(FaultKind::LaneDeath), "un-laned probe must not match lane step");
        assert!(!inj.with_lane(0).fire(FaultKind::LaneDeath), "wrong lane");
        assert!(inj.with_lane(1).fire(FaultKind::LaneDeath));
        assert!(!inj.with_lane(1).fire(FaultKind::LaneDeath), "count exhausted");
        // count=2 consumed across probes (any lane: step has no lane)
        assert!(inj.fire(FaultKind::DiskError));
        assert!(inj.with_lane(3).fire(FaultKind::DiskError));
        assert!(!inj.fire(FaultKind::DiskError));
        assert_eq!(inj.snapshot().faults_injected, 3);
        let off = FaultInjector::off();
        assert!(!off.fire(FaultKind::DiskError));
        assert!(!off.is_on());
    }

    #[test]
    fn disarm_stops_firing() {
        let inj = FaultInjector::from_arg("disk_error@0x100").expect("plan");
        assert!(inj.fire(FaultKind::DiskError));
        inj.disarm();
        assert!(!inj.fire(FaultKind::DiskError));
    }

    #[test]
    fn retry_backoff_is_deterministic_and_bounded() {
        let p = RetryPolicy { max_retries: 3, base_backoff_ms: 2, seed: 42 };
        let a: Vec<u64> = (1..=3).map(|i| p.backoff_ms(9, i)).collect();
        let b: Vec<u64> = (1..=3).map(|i| p.backoff_ms(9, i)).collect();
        assert_eq!(a, b, "same seed+salt must replay the same schedule");
        let c: Vec<u64> = (1..=3).map(|i| p.backoff_ms(10, i)).collect();
        assert_ne!(a, c, "different salt should (almost surely) jitter differently");
        for (i, ms) in a.iter().enumerate() {
            let attempt = i as u32 + 1;
            assert!(*ms >= 2 << attempt.min(16), "below exponential base");
            assert!(*ms < (2 << attempt.min(16)) + 2, "jitter exceeds base");
        }
    }

    #[test]
    fn watchdog_fires_on_timeout_and_not_on_disarm() {
        let wd = Watchdog::new();
        let hits = Arc::new(AtomicUsize::new(0));

        // disarmed in time: no fire
        let h = hits.clone();
        {
            let _g = wd.arm(Duration::from_millis(200), move || {
                h.fetch_add(1, Ordering::SeqCst);
            });
            std::thread::sleep(Duration::from_millis(10));
        }
        std::thread::sleep(Duration::from_millis(250));
        assert_eq!(hits.load(Ordering::SeqCst), 0, "disarm must cancel the action");
        assert_eq!(wd.fired(), 0);

        // timed out: fires exactly once
        let h = hits.clone();
        let g = wd.arm(Duration::from_millis(20), move || {
            h.fetch_add(1, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(150));
        assert_eq!(hits.load(Ordering::SeqCst), 1);
        assert!(g.expired());
        assert_eq!(wd.fired(), 1);
        drop(g);

        // re-arm still works after a fire
        let h = hits.clone();
        let _g = wd.arm(Duration::from_millis(20), move || {
            h.fetch_add(1, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(150));
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }
}
