//! # Hermes — memory-efficient PIPELOAD pipeline inference (reproduction)
//!
//! Reproduction of *"Hermes: Memory-Efficient Pipeline Inference for Large
//! Models on Edge Devices"* (Han et al., CS.DC 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system: PIPELOAD's Loading Agents /
//!   Inference Agent / Daemon Agent ([`pipeload`]), the Baseline and
//!   PipeSwitch-style comparators ([`baseline`]), and the Hermes framework
//!   ([`profiler`], [`planner`], [`engine`], [`server`]).
//! * **L2/L1 (python, build-time only)** — per-layer-type JAX forwards
//!   calling a Pallas flash-attention kernel, AOT-lowered to HLO text;
//!   loaded and executed here via PJRT ([`runtime`]).
//!
//! Weights are runtime parameters streamed from `.hws` shards
//! ([`weights`]) through an edge-storage simulator ([`diskio`]), gated by
//! the Daemon's memory accountant ([`memory`]).  See DESIGN.md for the
//! full inventory and EXPERIMENTS.md for paper-vs-measured results.

pub mod analyze;
pub mod baseline;
pub mod config;
pub mod diskio;
pub mod elastic;
pub mod engine;
pub mod faults;
pub mod kvcache;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod pipeload;
pub mod planner;
pub mod profiler;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod server;
pub mod signals;
pub mod telemetry;
pub mod trace;
pub mod util;
pub mod weights;
