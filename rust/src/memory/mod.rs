//! Memory accountant: the Daemon Agent's budget enforcement.
//!
//! The paper enforces edge-device memory limits with `docker --memory`; the
//! PIPELOAD daemon reacts to its *own* usage tracking and pauses Loading
//! Agents (the `S^stop` signal) when the budget would be exceeded.  This
//! module is that tracking: `acquire()` blocks while `used + want > budget`
//! (the loading agent is "stopped"), `free()` (the daemon's destruction)
//! wakes the waiters.  Peak usage is the paper's "memory footprint" metric
//! (max occupancy over the execution lifecycle).

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

#[derive(Debug)]
struct State {
    used: u64,
    peak: u64,
    budget: Option<u64>,
    shutdown: bool,
    /// cumulative time any acquirer spent blocked (the paper's stall time)
    stalled: Duration,
    stall_events: u64,
}

/// Thread-safe budget accountant; clone freely (Arc inside).
#[derive(Debug, Clone)]
pub struct MemoryAccountant {
    inner: Arc<(Mutex<State>, Condvar)>,
}

impl MemoryAccountant {
    pub fn new(budget: Option<u64>) -> MemoryAccountant {
        MemoryAccountant {
            inner: Arc::new((
                Mutex::new(State {
                    used: 0,
                    peak: 0,
                    budget,
                    shutdown: false,
                    stalled: Duration::ZERO,
                    stall_events: 0,
                }),
                Condvar::new(),
            )),
        }
    }

    pub fn unlimited() -> MemoryAccountant {
        MemoryAccountant::new(None)
    }

    /// Block until `bytes` fit under the budget, then account them.
    /// Returns how long the caller was stalled (S^stop duration).
    /// Errors on shutdown or if `bytes` alone exceeds the budget (a single
    /// layer that can never fit — a planning error, not a transient).
    pub fn acquire(&self, bytes: u64) -> Result<Duration> {
        let (lock, cv) = &*self.inner;
        let mut s = lock.lock().unwrap();
        if let Some(b) = s.budget {
            if bytes > b {
                bail!("allocation of {bytes} B can never fit budget {b} B");
            }
        }
        let t0 = Instant::now();
        let mut stalled = false;
        while !s.shutdown && s.budget.map(|b| s.used + bytes > b).unwrap_or(false) {
            stalled = true;
            s = cv.wait_timeout(s, Duration::from_millis(100)).unwrap().0;
        }
        if s.shutdown {
            bail!("accountant shut down");
        }
        let waited = t0.elapsed();
        if stalled {
            s.stalled += waited;
            s.stall_events += 1;
        }
        s.used += bytes;
        s.peak = s.peak.max(s.used);
        Ok(waited)
    }

    /// Non-blocking acquire; false if it would exceed the budget.
    pub fn try_acquire(&self, bytes: u64) -> bool {
        self.try_acquire_reserving(bytes, 0)
    }

    /// Non-blocking acquire that additionally keeps `reserve` bytes of
    /// headroom untouched: succeeds only if `used + bytes + reserve` fits
    /// the budget.  Speculative callers (cross-pass prefetch) use the
    /// running pass's `max_stage` as the reserve, so speculation can never
    /// consume the slack the pass's own next admission needs.
    pub fn try_acquire_reserving(&self, bytes: u64, reserve: u64) -> bool {
        let (lock, _) = &*self.inner;
        let mut s = lock.lock().unwrap();
        if s.shutdown || s.budget.map(|b| s.used + bytes + reserve > b).unwrap_or(false) {
            return false;
        }
        s.used += bytes;
        s.peak = s.peak.max(s.used);
        true
    }

    /// Would acquiring `bytes` right now exceed the budget?  (Snapshot —
    /// callers that need atomicity use [`MemoryAccountant::try_acquire`];
    /// the hot-layer cache uses this to decide how far to evict.)
    pub fn would_block(&self, bytes: u64) -> bool {
        let s = self.inner.0.lock().unwrap();
        s.budget.map(|b| s.used + bytes > b).unwrap_or(false)
    }

    /// Account bytes that must not block (activations on the compute path).
    /// May push usage above the budget; peak still records it honestly.
    pub fn force_add(&self, bytes: u64) {
        let (lock, _) = &*self.inner;
        let mut s = lock.lock().unwrap();
        s.used += bytes;
        s.peak = s.peak.max(s.used);
    }

    /// Release bytes (the daemon's memory destruction) and wake waiters.
    pub fn free(&self, bytes: u64) {
        let (lock, cv) = &*self.inner;
        let mut s = lock.lock().unwrap();
        assert!(s.used >= bytes, "free({bytes}) underflows used={}", s.used);
        s.used -= bytes;
        cv.notify_all();
    }

    /// Abort all waiters (pipeline teardown on error).
    pub fn shutdown(&self) {
        let (lock, cv) = &*self.inner;
        lock.lock().unwrap().shutdown = true;
        cv.notify_all();
    }

    pub fn used(&self) -> u64 {
        self.inner.0.lock().unwrap().used
    }

    pub fn peak(&self) -> u64 {
        self.inner.0.lock().unwrap().peak
    }

    pub fn budget(&self) -> Option<u64> {
        self.inner.0.lock().unwrap().budget
    }

    /// Total time acquirers spent blocked + how many times they blocked.
    pub fn stall_stats(&self) -> (Duration, u64) {
        let s = self.inner.0.lock().unwrap();
        (s.stalled, s.stall_events)
    }

    /// Start a new peak-measurement window at the current occupancy.
    /// Sessions call this at pass boundaries so each pass reports its own
    /// peak while pinned hot layers stay accounted across passes.
    pub fn reset_peak_to_used(&self) {
        let mut s = self.inner.0.lock().unwrap();
        s.peak = s.used;
    }

    /// Replace the budget at run time (the elastic memory controller's
    /// primitive; see [`crate::elastic`]).  Growing wakes blocked waiters —
    /// the new headroom may admit them.  Shrinking only changes the bound:
    /// `used` may now exceed it, and it is the caller's job to drive the
    /// eviction chain (pinned layers first, then KV sequences, via
    /// `OrderedGate::reclaim_to_budget`) until `used <= budget` again; the
    /// accountant itself owns no evictable state.
    pub fn resize(&self, new_budget: Option<u64>) {
        let (lock, cv) = &*self.inner;
        let mut s = lock.lock().unwrap();
        s.budget = new_budget;
        cv.notify_all();
    }

    /// Bytes currently accounted above the budget (0 when unconstrained or
    /// within bounds) — how much an elastic shrink still has to reclaim.
    pub fn over_budget_bytes(&self) -> u64 {
        let s = self.inner.0.lock().unwrap();
        match s.budget {
            Some(b) => s.used.saturating_sub(b),
            None => 0,
        }
    }

    /// Clear a shutdown without touching usage (multi-session recovery: one
    /// session's failed pass must not permanently poison an accountant that
    /// other sessions still account into).
    pub fn revive(&self) {
        let (lock, cv) = &*self.inner;
        lock.lock().unwrap().shutdown = false;
        cv.notify_all();
    }

    /// Reset usage/peak/stall counters, keeping the budget (profiler reuse).
    pub fn reset(&self) {
        let (lock, cv) = &*self.inner;
        let mut s = lock.lock().unwrap();
        s.used = 0;
        s.peak = 0;
        s.stalled = Duration::ZERO;
        s.stall_events = 0;
        s.shutdown = false;
        cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_used_and_peak() {
        let m = MemoryAccountant::unlimited();
        m.acquire(100).unwrap();
        m.acquire(50).unwrap();
        assert_eq!(m.used(), 150);
        m.free(120);
        assert_eq!(m.used(), 30);
        assert_eq!(m.peak(), 150);
    }

    #[test]
    #[should_panic(expected = "underflows")]
    fn free_underflow_panics() {
        let m = MemoryAccountant::unlimited();
        m.acquire(10).unwrap();
        m.free(20);
    }

    #[test]
    fn oversized_allocation_rejected() {
        let m = MemoryAccountant::new(Some(100));
        assert!(m.acquire(101).is_err());
    }

    #[test]
    fn blocks_until_freed() {
        let m = MemoryAccountant::new(Some(100));
        m.acquire(80).unwrap();
        let m2 = m.clone();
        let h = std::thread::spawn(move || m2.acquire(50).unwrap());
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(m.used(), 80); // still blocked
        m.free(80);
        let waited = h.join().unwrap();
        assert!(waited.as_millis() >= 40);
        assert_eq!(m.used(), 50);
        let (stalled, events) = m.stall_stats();
        assert!(stalled.as_millis() >= 40);
        assert_eq!(events, 1);
    }

    #[test]
    fn try_acquire_respects_budget() {
        let m = MemoryAccountant::new(Some(100));
        assert!(m.try_acquire(60));
        assert!(!m.try_acquire(60));
        m.free(60);
        assert!(m.try_acquire(60));
    }

    #[test]
    fn try_acquire_reserving_keeps_headroom() {
        let m = MemoryAccountant::new(Some(100));
        assert!(!m.try_acquire_reserving(80, 30), "80 + 30 reserve > 100");
        assert!(m.try_acquire_reserving(70, 30));
        assert_eq!(m.used(), 70);
        assert!(!m.try_acquire_reserving(1, 30));
        // plain acquire may still take the reserved slack
        assert!(m.try_acquire(30));
        // unconstrained budget never blocks
        let u = MemoryAccountant::unlimited();
        assert!(u.try_acquire_reserving(1 << 40, 1 << 40));
    }

    #[test]
    fn force_add_exceeds_budget_but_records_peak() {
        let m = MemoryAccountant::new(Some(100));
        m.acquire(90).unwrap();
        m.force_add(30);
        assert_eq!(m.used(), 120);
        assert_eq!(m.peak(), 120);
    }

    #[test]
    fn shutdown_unblocks_waiters_with_error() {
        let m = MemoryAccountant::new(Some(10));
        m.acquire(10).unwrap();
        let m2 = m.clone();
        let h = std::thread::spawn(move || m2.acquire(5));
        std::thread::sleep(Duration::from_millis(30));
        m.shutdown();
        assert!(h.join().unwrap().is_err());
    }

    #[test]
    fn revive_clears_shutdown_only() {
        let m = MemoryAccountant::new(Some(100));
        m.acquire(40).unwrap();
        m.shutdown();
        assert!(m.acquire(10).is_err());
        m.revive();
        m.acquire(10).unwrap();
        assert_eq!(m.used(), 50, "revive must not touch usage");
    }

    #[test]
    fn reset_clears_counters() {
        let m = MemoryAccountant::new(Some(100));
        m.acquire(70).unwrap();
        m.free(70);
        m.reset();
        assert_eq!(m.used(), 0);
        assert_eq!(m.peak(), 0);
        assert_eq!(m.budget(), Some(100));
    }

    #[test]
    fn would_block_tracks_budget_headroom() {
        let m = MemoryAccountant::new(Some(100));
        assert!(!m.would_block(100));
        m.acquire(60).unwrap();
        assert!(!m.would_block(40));
        assert!(m.would_block(41));
        let unlimited = MemoryAccountant::unlimited();
        assert!(!unlimited.would_block(u64::MAX));
    }

    #[test]
    fn reset_peak_to_used_starts_new_window() {
        let m = MemoryAccountant::unlimited();
        m.acquire(100).unwrap();
        m.free(80);
        assert_eq!(m.peak(), 100);
        m.reset_peak_to_used();
        assert_eq!(m.peak(), 20);
        m.acquire(30).unwrap();
        assert_eq!(m.peak(), 50);
    }

    #[test]
    fn resize_grow_wakes_waiters() {
        let m = MemoryAccountant::new(Some(100));
        m.acquire(100).unwrap();
        let m2 = m.clone();
        let h = std::thread::spawn(move || m2.acquire(50).unwrap());
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(m.used(), 100); // still blocked
        m.resize(Some(200));
        h.join().unwrap();
        assert_eq!(m.used(), 150);
        assert_eq!(m.budget(), Some(200));
    }

    #[test]
    fn resize_shrink_reports_overage_without_evicting() {
        let m = MemoryAccountant::new(Some(100));
        m.acquire(80).unwrap();
        assert_eq!(m.over_budget_bytes(), 0);
        m.resize(Some(50));
        assert_eq!(m.used(), 80, "resize never touches usage");
        assert_eq!(m.over_budget_bytes(), 30);
        assert!(m.would_block(0));
        m.resize(None);
        assert_eq!(m.over_budget_bytes(), 0);
    }

    #[test]
    fn concurrent_acquire_free_consistency() {
        let m = MemoryAccountant::new(Some(1000));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..200 {
                    m.acquire(10).unwrap();
                    m.free(10);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.used(), 0);
        assert!(m.peak() <= 1000);
    }
}
