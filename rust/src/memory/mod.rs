//! Memory accountant: the Daemon Agent's budget enforcement.
//!
//! The paper enforces edge-device memory limits with `docker --memory`; the
//! PIPELOAD daemon reacts to its *own* usage tracking and pauses Loading
//! Agents (the `S^stop` signal) when the budget would be exceeded.  This
//! module is that tracking: `acquire()` blocks while `used + want > budget`
//! (the loading agent is "stopped"), `free()` (the daemon's destruction)
//! wakes the waiters.  Peak usage is the paper's "memory footprint" metric
//! (max occupancy over the execution lifecycle).
//!
//! # Per-pass ledgers (concurrent lanes)
//!
//! One accountant may be shared by several sessions whose passes run
//! **concurrently** (the Router's lane executors).  Each in-flight pass
//! owns a [`PassLedger`]: every transient byte the pass holds is charged
//! against the shared budget *and* recorded in the ledger, and bytes that
//! move into a durable store (pin cache, prefetch buffer, device cache)
//! are [`PassLedger::release`]d to it.  A failed pass recovers by
//! [`PassLedger::drain`]ing exactly its own outstanding bytes — no
//! snapshot arithmetic over `used`, which is only exact when passes are
//! serialized.
//!
//! Waiter wakeup is notification-driven: every mutation that can unblock
//! an `acquire` (`free`, `resize`, `reset`, `shutdown`, `revive`)
//! notifies the condvar, so blocked chargers need no poll timeout even
//! with many lanes charging concurrently.

use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::faults::{FaultInjector, FaultKind};

#[derive(Debug)]
struct State {
    used: u64,
    peak: u64,
    budget: Option<u64>,
    shutdown: bool,
    /// cumulative time any acquirer spent blocked (the paper's stall time)
    stalled: Duration,
    stall_events: u64,
    /// `acquire_fail` probe (`--fault-plan`): admissions transiently refused
    faults: FaultInjector,
}

/// Poison-tolerant lock.  Every critical section here leaves `State` (or a
/// ledger balance) consistent — single-field arithmetic, no multi-step
/// invariants — so a panicking holder must not wedge every other lane:
/// recovery keeps accounting instead of propagating the poison.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Thread-safe budget accountant; clone freely (Arc inside).
#[derive(Debug, Clone)]
pub struct MemoryAccountant {
    inner: Arc<(Mutex<State>, Condvar)>,
}

impl MemoryAccountant {
    pub fn new(budget: Option<u64>) -> MemoryAccountant {
        MemoryAccountant {
            inner: Arc::new((
                Mutex::new(State {
                    used: 0,
                    peak: 0,
                    budget,
                    shutdown: false,
                    stalled: Duration::ZERO,
                    stall_events: 0,
                    faults: FaultInjector::off(),
                }),
                Condvar::new(),
            )),
        }
    }

    pub fn unlimited() -> MemoryAccountant {
        MemoryAccountant::new(None)
    }

    /// Attach a fault injector (shared through the Arc: every clone sees
    /// it).  `acquire_fail` steps make admissions transiently refuse.
    pub fn set_faults(&self, faults: FaultInjector) {
        relock(&self.inner.0).faults = faults;
    }

    /// Block until `bytes` fit under the budget, then account them.
    /// Returns how long the caller was stalled (S^stop duration).
    /// Errors on shutdown or if `bytes` alone exceeds the budget (a single
    /// layer that can never fit — a planning error, not a transient).
    pub fn acquire(&self, bytes: u64) -> Result<Duration> {
        let (lock, cv) = &*self.inner;
        let mut s = relock(lock);
        if let Some(b) = s.budget {
            if bytes > b {
                bail!("allocation of {bytes} B can never fit budget {b} B");
            }
        }
        let t0 = Instant::now();
        let mut stalled = false;
        // Pure notification wait: every used-decreasing or budget-changing
        // mutation notifies, so no poll timeout is needed even with many
        // concurrent chargers (a timeout here would just hide a lost-wakeup
        // bug instead of surfacing it).
        loop {
            if s.shutdown {
                bail!("accountant shut down");
            }
            if s.budget.map(|b| s.used + bytes > b).unwrap_or(false) {
                stalled = true;
                s = cv.wait(s).unwrap_or_else(std::sync::PoisonError::into_inner);
                continue;
            }
            // Injected transient refusal (`acquire_fail`): park briefly and
            // re-check.  The plan's `count` bounds total refusals, so this
            // self-recovers by bounded retry instead of surfacing an error.
            if s.faults.fire(FaultKind::AcquireFail) {
                stalled = true;
                let (ns, _) = cv
                    .wait_timeout(s, Duration::from_millis(1))
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                s = ns;
                continue;
            }
            break;
        }
        let waited = t0.elapsed();
        if stalled {
            s.stalled += waited;
            s.stall_events += 1;
        }
        s.used += bytes;
        s.peak = s.peak.max(s.used);
        Ok(waited)
    }

    /// Non-blocking acquire; false if it would exceed the budget.
    pub fn try_acquire(&self, bytes: u64) -> bool {
        self.try_acquire_reserving(bytes, 0)
    }

    /// Non-blocking acquire that additionally keeps `reserve` bytes of
    /// headroom untouched: succeeds only if `used + bytes + reserve` fits
    /// the budget.  Speculative callers (cross-pass prefetch) use the
    /// running pass's `max_stage` as the reserve, so speculation can never
    /// consume the slack the pass's own next admission needs.
    pub fn try_acquire_reserving(&self, bytes: u64, reserve: u64) -> bool {
        let (lock, _) = &*self.inner;
        let mut s = relock(lock);
        if s.shutdown || s.budget.map(|b| s.used + bytes + reserve > b).unwrap_or(false) {
            return false;
        }
        // injected transient refusal: callers already treat `false` as
        // budget pressure and retry/evict, which IS the recovery path
        if s.faults.fire(FaultKind::AcquireFail) {
            return false;
        }
        s.used += bytes;
        s.peak = s.peak.max(s.used);
        true
    }

    /// Would acquiring `bytes` right now exceed the budget?  (Snapshot —
    /// callers that need atomicity use [`MemoryAccountant::try_acquire`];
    /// the hot-layer cache uses this to decide how far to evict.)
    pub fn would_block(&self, bytes: u64) -> bool {
        let s = relock(&self.inner.0);
        s.budget.map(|b| s.used + bytes > b).unwrap_or(false)
    }

    /// Account bytes that must not block (activations on the compute path).
    /// May push usage above the budget; peak still records it honestly.
    pub fn force_add(&self, bytes: u64) {
        let (lock, _) = &*self.inner;
        let mut s = relock(lock);
        s.used += bytes;
        s.peak = s.peak.max(s.used);
    }

    /// Release bytes (the daemon's memory destruction) and wake waiters.
    pub fn free(&self, bytes: u64) {
        let (lock, cv) = &*self.inner;
        let mut s = relock(lock);
        assert!(s.used >= bytes, "free({bytes}) underflows used={}", s.used);
        s.used -= bytes;
        cv.notify_all();
    }

    /// Abort all waiters (pipeline teardown on error).
    pub fn shutdown(&self) {
        let (lock, cv) = &*self.inner;
        relock(lock).shutdown = true;
        cv.notify_all();
    }

    pub fn used(&self) -> u64 {
        relock(&self.inner.0).used
    }

    pub fn peak(&self) -> u64 {
        relock(&self.inner.0).peak
    }

    pub fn budget(&self) -> Option<u64> {
        relock(&self.inner.0).budget
    }

    /// Total time acquirers spent blocked + how many times they blocked.
    pub fn stall_stats(&self) -> (Duration, u64) {
        let s = relock(&self.inner.0);
        (s.stalled, s.stall_events)
    }

    /// Start a new peak-measurement window at the current occupancy.
    /// Sessions call this at pass boundaries so each pass reports its own
    /// peak while pinned hot layers stay accounted across passes.
    pub fn reset_peak_to_used(&self) {
        let mut s = relock(&self.inner.0);
        s.peak = s.used;
    }

    /// Replace the budget at run time (the elastic memory controller's
    /// primitive; see [`crate::elastic`]).  Growing wakes blocked waiters —
    /// the new headroom may admit them.  Shrinking only changes the bound:
    /// `used` may now exceed it, and it is the caller's job to drive the
    /// eviction chain (pinned layers first, then KV sequences, via
    /// `OrderedGate::reclaim_to_budget`) until `used <= budget` again; the
    /// accountant itself owns no evictable state.
    pub fn resize(&self, new_budget: Option<u64>) {
        let (lock, cv) = &*self.inner;
        let mut s = relock(lock);
        s.budget = new_budget;
        cv.notify_all();
    }

    /// Bytes currently accounted above the budget (0 when unconstrained or
    /// within bounds) — how much an elastic shrink still has to reclaim.
    pub fn over_budget_bytes(&self) -> u64 {
        let s = relock(&self.inner.0);
        match s.budget {
            Some(b) => s.used.saturating_sub(b),
            None => 0,
        }
    }

    /// Clear a shutdown without touching usage (multi-session recovery: one
    /// session's failed pass must not permanently poison an accountant that
    /// other sessions still account into).
    pub fn revive(&self) {
        let (lock, cv) = &*self.inner;
        relock(lock).shutdown = false;
        cv.notify_all();
    }

    /// Reset usage/peak/stall counters, keeping the budget (profiler reuse).
    pub fn reset(&self) {
        let (lock, cv) = &*self.inner;
        let mut s = relock(lock);
        s.used = 0;
        s.peak = 0;
        s.stalled = Duration::ZERO;
        s.stall_events = 0;
        s.shutdown = false;
        cv.notify_all();
    }

    /// A fresh per-pass ledger charged against this accountant.
    pub fn pass_ledger(&self) -> PassLedger {
        PassLedger { accountant: self.clone(), held: Arc::new(Mutex::new(0)) }
    }
}

/// Per-pass byte ledger over a (possibly shared) [`MemoryAccountant`].
///
/// Every transient byte an in-flight pass holds — admitted weights riding
/// loader channels, device-copy uploads, activations — is charged through
/// the ledger, so the pass always knows exactly how many accounted bytes
/// are *its own*.  Bytes whose ownership moves between the pass and a
/// durable store (pin cache, prefetch buffer, device cache) transfer with
/// [`PassLedger::adopt`] / [`PassLedger::release`] without touching
/// accountant usage.  Failed-pass recovery calls [`PassLedger::drain`]:
/// it returns the pass's outstanding bytes to the budget and nothing
/// else, which stays exact while other lanes' passes charge the same
/// accountant concurrently (the snapshot arithmetic this replaces was
/// only correct with one pass in flight).
///
/// A byte's lifecycle through the ledger is sequential (charged before it
/// can be freed), so the two-lock update (accountant, then ledger) never
/// underflows even though it is not atomic; `drain` runs only after the
/// pass's workers have quiesced.
#[derive(Debug, Clone)]
pub struct PassLedger {
    accountant: MemoryAccountant,
    held: Arc<Mutex<u64>>,
}

impl PassLedger {
    /// Blocking charge: accountant admission + ledger record.
    pub fn acquire(&self, bytes: u64) -> Result<Duration> {
        let waited = self.accountant.acquire(bytes)?;
        *relock(&self.held) += bytes;
        Ok(waited)
    }

    /// Non-blocking charge; false if it would exceed the budget.
    pub fn try_acquire(&self, bytes: u64) -> bool {
        self.try_acquire_reserving(bytes, 0)
    }

    /// Non-blocking charge preserving `reserve` bytes of headroom.
    pub fn try_acquire_reserving(&self, bytes: u64, reserve: u64) -> bool {
        if !self.accountant.try_acquire_reserving(bytes, reserve) {
            return false;
        }
        *relock(&self.held) += bytes;
        true
    }

    /// Charge bytes that must not block (compute-path transients); may
    /// push the accountant above budget, exactly like
    /// [`MemoryAccountant::force_add`].
    pub fn force_add(&self, bytes: u64) {
        self.accountant.force_add(bytes);
        *relock(&self.held) += bytes;
    }

    /// Return pass-owned bytes to the budget (discharge + accountant free).
    pub fn free(&self, bytes: u64) {
        self.discharge(bytes);
        self.accountant.free(bytes);
    }

    /// Take ownership of bytes a store already accounts (a pinned layer or
    /// prefetched shard handed to this pass): ledger only, usage unchanged.
    pub fn adopt(&self, bytes: u64) {
        *relock(&self.held) += bytes;
    }

    /// Hand pass-owned bytes to a durable store (pin / device-retain /
    /// prefetch-park): they stay accounted but are no longer this pass's
    /// to drain.
    pub fn release(&self, bytes: u64) {
        self.discharge(bytes);
    }

    fn discharge(&self, bytes: u64) {
        let mut held = relock(&self.held);
        assert!(*held >= bytes, "ledger discharge({bytes}) underflows held={held}");
        *held -= bytes;
    }

    /// Bytes the pass currently holds.
    pub fn balance(&self) -> u64 {
        *relock(&self.held)
    }

    /// Free every byte the pass still holds (failed-pass recovery);
    /// returns how many were drained.
    pub fn drain(&self) -> u64 {
        let leaked = {
            let mut held = relock(&self.held);
            std::mem::take(&mut *held)
        };
        if leaked > 0 {
            self.accountant.free(leaked);
        }
        leaked
    }

    pub fn accountant(&self) -> &MemoryAccountant {
        &self.accountant
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_used_and_peak() {
        let m = MemoryAccountant::unlimited();
        m.acquire(100).unwrap();
        m.acquire(50).unwrap();
        assert_eq!(m.used(), 150);
        m.free(120);
        assert_eq!(m.used(), 30);
        assert_eq!(m.peak(), 150);
    }

    #[test]
    #[should_panic(expected = "underflows")]
    fn free_underflow_panics() {
        let m = MemoryAccountant::unlimited();
        m.acquire(10).unwrap();
        m.free(20);
    }

    #[test]
    fn oversized_allocation_rejected() {
        let m = MemoryAccountant::new(Some(100));
        assert!(m.acquire(101).is_err());
    }

    #[test]
    fn blocks_until_freed() {
        let m = MemoryAccountant::new(Some(100));
        m.acquire(80).unwrap();
        let m2 = m.clone();
        let h = std::thread::spawn(move || m2.acquire(50).unwrap());
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(m.used(), 80); // still blocked
        m.free(80);
        let waited = h.join().unwrap();
        assert!(waited.as_millis() >= 40);
        assert_eq!(m.used(), 50);
        let (stalled, events) = m.stall_stats();
        assert!(stalled.as_millis() >= 40);
        assert_eq!(events, 1);
    }

    #[test]
    fn try_acquire_respects_budget() {
        let m = MemoryAccountant::new(Some(100));
        assert!(m.try_acquire(60));
        assert!(!m.try_acquire(60));
        m.free(60);
        assert!(m.try_acquire(60));
    }

    #[test]
    fn try_acquire_reserving_keeps_headroom() {
        let m = MemoryAccountant::new(Some(100));
        assert!(!m.try_acquire_reserving(80, 30), "80 + 30 reserve > 100");
        assert!(m.try_acquire_reserving(70, 30));
        assert_eq!(m.used(), 70);
        assert!(!m.try_acquire_reserving(1, 30));
        // plain acquire may still take the reserved slack
        assert!(m.try_acquire(30));
        // unconstrained budget never blocks
        let u = MemoryAccountant::unlimited();
        assert!(u.try_acquire_reserving(1 << 40, 1 << 40));
    }

    #[test]
    fn force_add_exceeds_budget_but_records_peak() {
        let m = MemoryAccountant::new(Some(100));
        m.acquire(90).unwrap();
        m.force_add(30);
        assert_eq!(m.used(), 120);
        assert_eq!(m.peak(), 120);
    }

    #[test]
    fn shutdown_unblocks_waiters_with_error() {
        let m = MemoryAccountant::new(Some(10));
        m.acquire(10).unwrap();
        let m2 = m.clone();
        let h = std::thread::spawn(move || m2.acquire(5));
        std::thread::sleep(Duration::from_millis(30));
        m.shutdown();
        assert!(h.join().unwrap().is_err());
    }

    #[test]
    fn revive_clears_shutdown_only() {
        let m = MemoryAccountant::new(Some(100));
        m.acquire(40).unwrap();
        m.shutdown();
        assert!(m.acquire(10).is_err());
        m.revive();
        m.acquire(10).unwrap();
        assert_eq!(m.used(), 50, "revive must not touch usage");
    }

    #[test]
    fn reset_clears_counters() {
        let m = MemoryAccountant::new(Some(100));
        m.acquire(70).unwrap();
        m.free(70);
        m.reset();
        assert_eq!(m.used(), 0);
        assert_eq!(m.peak(), 0);
        assert_eq!(m.budget(), Some(100));
    }

    #[test]
    fn would_block_tracks_budget_headroom() {
        let m = MemoryAccountant::new(Some(100));
        assert!(!m.would_block(100));
        m.acquire(60).unwrap();
        assert!(!m.would_block(40));
        assert!(m.would_block(41));
        let unlimited = MemoryAccountant::unlimited();
        assert!(!unlimited.would_block(u64::MAX));
    }

    #[test]
    fn reset_peak_to_used_starts_new_window() {
        let m = MemoryAccountant::unlimited();
        m.acquire(100).unwrap();
        m.free(80);
        assert_eq!(m.peak(), 100);
        m.reset_peak_to_used();
        assert_eq!(m.peak(), 20);
        m.acquire(30).unwrap();
        assert_eq!(m.peak(), 50);
    }

    #[test]
    fn resize_grow_wakes_waiters() {
        let m = MemoryAccountant::new(Some(100));
        m.acquire(100).unwrap();
        let m2 = m.clone();
        let h = std::thread::spawn(move || m2.acquire(50).unwrap());
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(m.used(), 100); // still blocked
        m.resize(Some(200));
        h.join().unwrap();
        assert_eq!(m.used(), 150);
        assert_eq!(m.budget(), Some(200));
    }

    #[test]
    fn resize_shrink_reports_overage_without_evicting() {
        let m = MemoryAccountant::new(Some(100));
        m.acquire(80).unwrap();
        assert_eq!(m.over_budget_bytes(), 0);
        m.resize(Some(50));
        assert_eq!(m.used(), 80, "resize never touches usage");
        assert_eq!(m.over_budget_bytes(), 30);
        assert!(m.would_block(0));
        m.resize(None);
        assert_eq!(m.over_budget_bytes(), 0);
    }

    #[test]
    fn pass_ledger_tracks_and_drains_own_bytes_only() {
        let m = MemoryAccountant::new(Some(100));
        let a = m.pass_ledger();
        let b = m.pass_ledger();
        a.acquire(30).unwrap();
        b.acquire(40).unwrap();
        a.force_add(10);
        assert_eq!(a.balance(), 40);
        assert_eq!(b.balance(), 40);
        assert_eq!(m.used(), 80);
        // a's recovery drains a's bytes alone; b's stay accounted
        assert_eq!(a.drain(), 40);
        assert_eq!(a.balance(), 0);
        assert_eq!(m.used(), 40);
        b.free(40);
        assert_eq!(m.used(), 0);
        assert_eq!(b.balance(), 0);
    }

    #[test]
    fn pass_ledger_ownership_transfers_keep_usage() {
        let m = MemoryAccountant::new(Some(100));
        let l = m.pass_ledger();
        l.acquire(50).unwrap();
        // pin: bytes leave the pass but stay accounted
        l.release(20);
        assert_eq!(l.balance(), 30);
        assert_eq!(m.used(), 50);
        // next pass takes the pinned layer back
        l.adopt(20);
        assert_eq!(l.balance(), 50);
        assert_eq!(m.used(), 50);
        l.free(50);
        assert_eq!(m.used(), 0);
        // drain with nothing held is a no-op
        assert_eq!(l.drain(), 0);
    }

    #[test]
    fn pass_ledger_try_acquire_respects_budget_and_reserve() {
        let m = MemoryAccountant::new(Some(100));
        let l = m.pass_ledger();
        assert!(!l.try_acquire_reserving(80, 30));
        assert!(l.try_acquire_reserving(60, 30));
        assert!(!l.try_acquire(50));
        assert!(l.try_acquire(40));
        assert_eq!(l.balance(), 100);
        assert_eq!(l.drain(), 100);
        assert_eq!(m.used(), 0);
    }

    #[test]
    #[should_panic(expected = "underflows")]
    fn pass_ledger_release_underflow_panics() {
        let m = MemoryAccountant::unlimited();
        let l = m.pass_ledger();
        l.force_add(5);
        l.release(6);
    }

    #[test]
    fn concurrent_ledgers_drain_exactly_under_contention() {
        let m = MemoryAccountant::new(Some(1000));
        let mut handles = Vec::new();
        for i in 0..6 {
            let l = m.pass_ledger();
            handles.push(std::thread::spawn(move || {
                for k in 0..100 {
                    l.acquire(10).unwrap();
                    if (i + k) % 3 == 0 {
                        l.drain(); // simulated failed-pass recovery
                    } else {
                        l.free(10);
                    }
                }
                assert_eq!(l.balance(), 0);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.used(), 0, "every lane returned exactly its own bytes");
        assert!(m.peak() <= 1000);
    }

    #[test]
    fn concurrent_acquire_free_consistency() {
        let m = MemoryAccountant::new(Some(1000));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..200 {
                    m.acquire(10).unwrap();
                    m.free(10);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.used(), 0);
        assert!(m.peak() <= 1000);
    }
}
