//! Seeded xoshiro256++ PRNG (no `rand` crate in the offline set).
//!
//! Used for synthetic weight generation (`hermes gen-weights`), workload
//! generators, and the property-test mini-framework. Deterministic across
//! runs for a given seed — required so benches and tests are reproducible.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (per loading agent / per tensor).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with the given mean (used for request arrival processes).
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Shuffle a slice (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
        // zero seed must not produce a degenerate all-zero state
        let mut z = Rng::new(0);
        assert_ne!(z.next_u64(), 0u64.wrapping_add(z.next_u64()));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.range(5, 8);
            assert!((5..8).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_diverge() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
