//! Micro-benchmark harness (no `criterion` in the offline crate set).
//!
//! Drives the `cargo bench` targets (declared with `harness = false`):
//! warmup, fixed-duration or fixed-iteration sampling, and robust stats
//! (median, mean, p95, stddev, min/max).  Timings use `Instant`; results
//! can be dumped as JSON for the EXPERIMENTS.md perf log.

use std::time::{Duration, Instant};

use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn from_samples(name: &str, mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| ns[(((n - 1) as f64) * p) as usize];
        Stats {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            median_ns: pct(0.5),
            p95_ns: pct(0.95),
            stddev_ns: var.sqrt(),
            min_ns: ns[0],
            max_ns: ns[n - 1],
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("name", self.name.clone())
            .set("iters", self.iters)
            .set("mean_ns", self.mean_ns)
            .set("median_ns", self.median_ns)
            .set("p95_ns", self.p95_ns)
            .set("stddev_ns", self.stddev_ns)
            .set("min_ns", self.min_ns)
            .set("max_ns", self.max_ns)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bencher {
    pub warmup: Duration,
    pub target: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        // HERMES_BENCH_FAST=1 shrinks budgets so CI smoke runs stay quick.
        let fast = std::env::var("HERMES_BENCH_FAST").is_ok();
        Bencher {
            warmup: if fast { Duration::from_millis(50) } else { Duration::from_millis(300) },
            target: if fast { Duration::from_millis(300) } else { Duration::from_secs(2) },
            min_iters: if fast { 3 } else { 10 },
            max_iters: if fast { 50 } else { 10_000 },
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Bencher {
        Bencher::default()
    }

    /// Benchmark `f`, printing a criterion-style line.
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &Stats {
        // warmup
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // estimate per-iter cost from one timed call
        let t = Instant::now();
        std::hint::black_box(f());
        let est = t.elapsed().max(Duration::from_nanos(50));
        let planned = ((self.target.as_nanos() / est.as_nanos().max(1)) as usize)
            .clamp(self.min_iters, self.max_iters);
        let mut samples = Vec::with_capacity(planned);
        for _ in 0..planned {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let s = Stats::from_samples(name, samples);
        println!(
            "{:<44} median {:>10}  mean {:>10}  p95 {:>10}  ({} iters)",
            s.name,
            fmt_ns(s.median_ns),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p95_ns),
            s.iters
        );
        self.results.push(s);
        self.results.last().unwrap()
    }

    /// One-shot measurement for expensive end-to-end runs (no warmup loop).
    pub fn once<R>(&mut self, name: &str, f: impl FnOnce() -> R) -> (R, Duration) {
        let t = Instant::now();
        let r = f();
        let d = t.elapsed();
        println!("{:<44} once   {:>10}", name, fmt_ns(d.as_nanos() as f64));
        self.results.push(Stats::from_samples(name, vec![d.as_nanos() as f64]));
        (r, d)
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Dump all results as a JSON array (for EXPERIMENTS.md §Perf logs).
    pub fn dump_json(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let v = Value::Arr(self.results.iter().map(|s| s.to_json()).collect());
        v.to_file(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples("t", vec![10.0, 20.0, 30.0, 40.0, 100.0]);
        assert_eq!(s.min_ns, 10.0);
        assert_eq!(s.max_ns, 100.0);
        assert_eq!(s.median_ns, 30.0);
        assert!(s.mean_ns > s.median_ns); // skewed sample
    }

    #[test]
    fn bench_runs_and_records() {
        std::env::set_var("HERMES_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let mut x = 0u64;
        b.bench("noop", || {
            x = x.wrapping_add(1);
            x
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].iters >= 3);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
