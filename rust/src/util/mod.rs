//! Hand-rolled substrate utilities.
//!
//! The offline crate set for this image contains only the `xla` crate's
//! dependency closure (no serde/clap/criterion/proptest/rand/tokio), so the
//! roles those crates usually play are implemented here and tested like any
//! other module. See DESIGN.md section 5.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

/// Format a byte count with binary units ("12.3 MiB").
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = b as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{x:.1} {}", UNITS[u])
    }
}

/// Format milliseconds compactly ("1.23 s" / "45.6 ms").
pub fn human_ms(ms: f64) -> String {
    if ms >= 1000.0 {
        format!("{:.2} s", ms / 1000.0)
    } else if ms >= 1.0 {
        format!("{ms:.1} ms")
    } else {
        format!("{:.0} µs", ms * 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn ms_formatting() {
        assert_eq!(human_ms(0.5), "500 µs");
        assert_eq!(human_ms(12.34), "12.3 ms");
        assert_eq!(human_ms(1500.0), "1.50 s");
    }
}
