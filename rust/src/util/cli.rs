//! Tiny CLI argument parser (no `clap` in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each subcommand declares its options up-front so `--help` output and
//! unknown-flag errors are accurate.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Declared option for help text + validation.
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

pub struct Args {
    values: HashMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv` against the declared options.
    pub fn parse(argv: &[String], opts: &[Opt]) -> Result<Args> {
        let decl: HashMap<&str, &Opt> = opts.iter().map(|o| (o.name, o)).collect();
        let mut values = HashMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (rest, None),
                };
                let Some(o) = decl.get(name) else {
                    bail!("unknown option --{name} (try --help)");
                };
                if o.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                        }
                    };
                    values.insert(name.to_string(), v);
                } else {
                    if inline.is_some() {
                        bail!("--{name} does not take a value");
                    }
                    flags.push(name.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        // apply defaults
        for o in opts {
            if let Some(d) = o.default {
                values.entry(o.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(Args { values, flags, positional })
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn req(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow::anyhow!("missing required --{name}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        Ok(self.req(name)?.parse()?)
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        Ok(self.req(name)?.parse()?)
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        Ok(self.req(name)?.parse()?)
    }

    /// Optional `--<name>` given in megabytes, returned as bytes.
    /// Shared by the budget-style knobs (`--budget-mb`, `--pin-budget-mb`).
    pub fn mb_bytes(&self, name: &str) -> Result<Option<u64>> {
        self.get(name)
            .map(|s| -> Result<u64> {
                let mb: f64 = s
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--{name} expects a number (MB), got '{s}'"))?;
                Ok((mb * 1024.0 * 1024.0) as u64)
            })
            .transpose()
    }

    /// Comma-separated list value.
    pub fn list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .map(|s| {
                s.split(',')
                    .map(|x| x.trim().to_string())
                    .filter(|x| !x.is_empty())
                    .collect()
            })
            .unwrap_or_default()
    }
}

pub fn render_help(cmd: &str, summary: &str, opts: &[Opt]) -> String {
    let mut s = format!("hermes {cmd} — {summary}\n\noptions:\n");
    for o in opts {
        let val = if o.takes_value { " <value>" } else { "" };
        let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        s.push_str(&format!("  --{}{}\n      {}{}\n", o.name, val, o.help, def));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> Vec<Opt> {
        vec![
            Opt { name: "model", takes_value: true, default: None, help: "" },
            Opt { name: "agents", takes_value: true, default: Some("4"), help: "" },
            Opt { name: "verbose", takes_value: false, default: None, help: "" },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_kv_and_flags() {
        let a = Args::parse(&sv(&["--model", "bert", "--verbose", "pos1"]), &opts()).unwrap();
        assert_eq!(a.get("model"), Some("bert"));
        assert_eq!(a.usize("agents").unwrap(), 4); // default
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn parse_eq_form() {
        let a = Args::parse(&sv(&["--model=vit", "--agents=6"]), &opts()).unwrap();
        assert_eq!(a.get("model"), Some("vit"));
        assert_eq!(a.usize("agents").unwrap(), 6);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(Args::parse(&sv(&["--nope"]), &opts()).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--model"]), &opts()).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(Args::parse(&sv(&["--verbose=1"]), &opts()).is_err());
    }

    #[test]
    fn mb_bytes_parsing() {
        let o = vec![Opt { name: "budget-mb", takes_value: true, default: None, help: "" }];
        let a = Args::parse(&sv(&["--budget-mb", "1.5"]), &o).unwrap();
        assert_eq!(a.mb_bytes("budget-mb").unwrap(), Some(1536 * 1024));
        let b = Args::parse(&sv(&[]), &o).unwrap();
        assert_eq!(b.mb_bytes("budget-mb").unwrap(), None);
        let c = Args::parse(&sv(&["--budget-mb", "lots"]), &o).unwrap();
        assert!(c.mb_bytes("budget-mb").is_err());
    }

    #[test]
    fn list_parsing() {
        let o = vec![Opt { name: "budgets", takes_value: true, default: None, help: "" }];
        let a = Args::parse(&sv(&["--budgets", "100, 200,300"]), &o).unwrap();
        assert_eq!(a.list("budgets"), vec!["100", "200", "300"]);
    }
}
