//! Minimal JSON parser/serializer.
//!
//! The offline crate set for this image has no serde, so everything that
//! crosses a process or language boundary (the AOT `manifest.json`, layer
//! profiles, planner schedules, trace dumps, golden vectors) goes through
//! this module.  Object key order is preserved (insertion order) so dumps
//! are stable and diffable.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value. Numbers are f64 (all our integers fit in 2^53).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Value> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Value::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn to_file(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    // -- constructors ------------------------------------------------------

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }

    pub fn int(n: i64) -> Value {
        Value::Num(n as f64)
    }

    pub fn obj() -> Value {
        Value::Obj(Vec::new())
    }

    /// Insert (or replace) a key in an object; panics on non-objects.
    pub fn set(mut self, key: &str, v: impl Into<Value>) -> Value {
        match &mut self {
            Value::Obj(map) => {
                let v = v.into();
                if let Some(slot) = map.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = v;
                } else {
                    map.push((key.to_string(), v));
                }
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(map) => map.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name (manifest parsing helper).
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 {
            bail!("negative where usize expected: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Value)]> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Convenience: object -> BTreeMap view (sorted iteration).
    pub fn obj_map(&self) -> Result<BTreeMap<&str, &Value>> {
        Ok(self.as_obj()?.iter().map(|(k, v)| (k.as_str(), v)).collect())
    }

    // -- serialization -----------------------------------------------------

    pub fn compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Value::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.compact())
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Value {
        Value::Num(n)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Value {
        Value::Num(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Value {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Value {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}
impl From<Vec<Value>> for Value {
    fn from(v: Vec<Value>) -> Value {
        Value::Arr(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, got '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut map = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            map.push((key, v));
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let cp = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| anyhow!("bad surrogate"))?;
                                    let lo = u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c).ok_or_else(|| anyhow!("bad codepoint"))?
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated utf8"))?;
                        s.push_str(std::str::from_utf8(bytes)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = text.parse().with_context(|| format!("bad number '{text}'"))?;
        Ok(Value::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(Value::parse("\"a\\nb\"").unwrap(), Value::str("a\nb"));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
        assert_eq!(v.get("c"), Some(&Value::Null));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"k":[1,2.5,"s\"q"],"o":{"n":null,"b":false},"e":[],"eo":{}}"#;
        let v = Value::parse(src).unwrap();
        let again = Value::parse(&v.compact()).unwrap();
        assert_eq!(v, again);
        let again = Value::parse(&v.pretty()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
        // raw multibyte utf-8 passes through
        let v = Value::parse("\"héllo😀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo😀");
        // escapes survive a round-trip
        let rt = Value::parse(&v.compact()).unwrap();
        assert_eq!(v, rt);
    }

    #[test]
    fn builder_and_set() {
        let v = Value::obj().set("a", 1i64).set("b", "x").set("a", 2i64);
        assert_eq!(v.get("a").unwrap().as_i64().unwrap(), 2);
        assert_eq!(v.as_obj().unwrap().len(), 2);
    }

    #[test]
    fn errors() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("nul").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integer_formatting_is_exact() {
        let v = Value::int(12354000000);
        assert_eq!(v.compact(), "12354000000");
    }
}
