//! Property-testing mini-framework (no `proptest` in the offline set).
//!
//! Runs a property over many seeded random cases; on failure it reports the
//! seed and case number so the exact case replays deterministically, then
//! attempts a bounded "shrink" by re-running with smaller size hints.
//!
//! Used by `rust/tests/prop_invariants.rs` for the coordinator invariants
//! (layer-assignment partition, inference ordering, accountant bounds,
//! planner monotonicity, shard round-trips).

use crate::util::rng::Rng;

/// Controls for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// max "size" hint passed to generators (shrink retries lower it)
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        // HERMES_PROP_SEED / HERMES_PROP_CASES override for replay.
        let seed = std::env::var("HERMES_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        let cases = std::env::var("HERMES_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Config { cases, seed, max_size: 64 }
    }
}

/// A generated case: the rng to draw from plus a size hint.
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.usize(lo, hi.max(lo + 1))
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range(lo, hi.max(lo + 1))
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool()
    }

    /// A vec with size-hint-bounded length.
    pub fn vec<T>(&mut self, min_len: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        let len = self.usize(min_len, min_len + self.size.max(1));
        (0..len).map(|_| f(self)).collect()
    }
}

/// Run `prop` over `cfg.cases` random cases. Panics with replay info on the
/// first failing case (after trying smaller sizes to find a simpler one).
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut failures: Option<(usize, usize, String)> = None;
    'outer: for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let mut g = Gen { rng: &mut rng, size: cfg.max_size };
        if let Err(msg) = prop(&mut g) {
            // bounded shrink: retry the same seed with smaller size hints
            for size in [1usize, 2, 4, 8, 16, 32] {
                if size >= cfg.max_size {
                    break;
                }
                let mut rng = Rng::new(case_seed);
                let mut g = Gen { rng: &mut rng, size };
                if let Err(small_msg) = prop(&mut g) {
                    failures = Some((case, size, small_msg));
                    break 'outer;
                }
            }
            failures = Some((case, cfg.max_size, msg));
            break 'outer;
        }
    }
    if let Some((case, size, msg)) = failures {
        panic!(
            "property '{name}' failed (case {case}, size {size}, replay with \
             HERMES_PROP_SEED={} HERMES_PROP_CASES={}):\n  {msg}",
            cfg.seed,
            case + 1
        );
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", Config { cases: 10, seed: 1, max_size: 8 }, |g| {
            n += 1;
            let x = g.usize(0, 100);
            if x < 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_replay() {
        check("fails", Config { cases: 10, seed: 2, max_size: 8 }, |g| {
            let v = g.vec(0, |g| g.usize(0, 10));
            if v.len() < 3 {
                Ok(())
            } else {
                Err(format!("len {}", v.len()))
            }
        });
    }

    #[test]
    fn deterministic_replay() {
        let mut first: Vec<usize> = Vec::new();
        check("record", Config { cases: 5, seed: 3, max_size: 8 }, |g| {
            first.push(g.usize(0, 1000));
            Ok(())
        });
        let mut second: Vec<usize> = Vec::new();
        check("record", Config { cases: 5, seed: 3, max_size: 8 }, |g| {
            second.push(g.usize(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
