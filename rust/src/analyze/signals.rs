//! Rolling-window derived signals over a live telemetry subscription.
//!
//! [`DerivedSignals`] attaches to a bus via [`Telemetry::subscribe`] —
//! a bounded ring the emit path appends to without ever blocking — and
//! folds the stream into the rates a controller (or an operator hitting
//! `{"op":"health"}`) actually wants:
//!
//! * per-lane **stall ratios** — what share of observed worker time each
//!   lane spent memory-stalled (`S^stop` pressure) vs pipeline-bubbled
//!   (waiting on loaders) vs computing,
//! * **shed rate by reason** — admission-control pressure as it happens,
//! * **prefetch waste rate** — speculative bytes bought and thrown away,
//! * **accountant high-water slope** — bytes/s trend of the per-pass
//!   peak, the early-warning signal an elastic controller reacts to.
//!
//! Everything is windowed (default 5 s): `poll()` drains the ring,
//! appends the new samples, evicts those older than the window, and
//! aggregates.  Polling is the *consumer's* cost — emitters only ever
//! pay one ring append.  This is the in-process consumer hook ROADMAP
//! item 4's closed-loop controller builds on.
//!
//! [`Telemetry::subscribe`]: crate::telemetry::Telemetry::subscribe

use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;
use std::time::Duration;

use crate::metrics::{prometheus_counter, prometheus_gauge};
use crate::telemetry::{Event, Phase, Subscription, Telemetry};
use crate::util::json::Value;

/// Default rolling-window width for the health surface.
pub const DEFAULT_WINDOW: Duration = Duration::from_secs(5);

/// Subscriber ring capacity: comfortably above the event rate of a busy
/// two-lane serve for one window, so drops mean a genuinely stuck
/// consumer rather than normal traffic.
const SUB_CAP: usize = 1 << 15;

/// One windowed sample, keyed by its end timestamp (µs on the bus clock).
enum Sample {
    StallMem { lane: u32, ms: f64 },
    StallWait { lane: u32, ms: f64 },
    Compute { lane: u32, ms: f64 },
    Shed { reason: String },
    Prefetch { bytes: u64 },
    Waste { bytes: u64 },
    HighWater { bytes: f64 },
    DecodeStep,
    Retire,
}

fn classify(ev: &Event) -> Option<Sample> {
    let ms = ev.dur_us as f64 / 1000.0;
    match (ev.name, ev.phase) {
        ("stall_mem", Phase::Complete) => Some(Sample::StallMem { lane: ev.lane, ms }),
        ("stall_wait", Phase::Complete) => Some(Sample::StallWait { lane: ev.lane, ms }),
        ("compute", Phase::Complete) => Some(Sample::Compute { lane: ev.lane, ms }),
        ("shed", Phase::Instant) => Some(Sample::Shed {
            reason: ev.args.reason.unwrap_or("unknown").to_string(),
        }),
        ("prefetch", Phase::Complete) => {
            Some(Sample::Prefetch { bytes: ev.args.bytes.unwrap_or(0) })
        }
        ("prefetch_waste", Phase::Instant) => {
            Some(Sample::Waste { bytes: ev.args.bytes.unwrap_or(0) })
        }
        ("mem_high_water", Phase::Counter) => {
            Some(Sample::HighWater { bytes: ev.args.value.unwrap_or(0.0).max(0.0) })
        }
        ("decode_step", Phase::Instant) => Some(Sample::DecodeStep),
        ("retire", Phase::Instant) => Some(Sample::Retire),
        _ => None,
    }
}

#[derive(Default)]
struct State {
    samples: VecDeque<(u64, Sample)>,
    events_seen: u64,
    high_water_last: u64,
}

/// Per-lane time split over the window.
#[derive(Debug, Clone, Copy, Default)]
pub struct LaneSignals {
    pub lane: u32,
    pub stall_mem_ms: f64,
    pub stall_wait_ms: f64,
    pub compute_ms: f64,
}

impl LaneSignals {
    fn busy_ms(&self) -> f64 {
        self.stall_mem_ms + self.stall_wait_ms + self.compute_ms
    }

    /// Share of observed worker time spent memory-stalled.
    pub fn stall_mem_ratio(&self) -> f64 {
        if self.busy_ms() <= 0.0 {
            0.0
        } else {
            self.stall_mem_ms / self.busy_ms()
        }
    }

    /// Share of observed worker time spent pipeline-bubbled.
    pub fn stall_wait_ratio(&self) -> f64 {
        if self.busy_ms() <= 0.0 {
            0.0
        } else {
            self.stall_wait_ms / self.busy_ms()
        }
    }
}

/// One aggregated view of the window — what `{"op":"health"}` returns.
#[derive(Debug, Clone, Default)]
pub struct SignalSnapshot {
    /// effective window width in seconds (shorter right after start-up)
    pub window_s: f64,
    /// false when the bus is disabled (no events will ever arrive)
    pub enabled: bool,
    pub lanes: Vec<LaneSignals>,
    pub shed_by_reason: BTreeMap<String, u64>,
    pub prefetch_bytes_per_s: f64,
    pub waste_bytes_per_s: f64,
    /// wasted / prefetched bytes in the window (0 when nothing prefetched)
    pub waste_ratio: f64,
    /// least-squares slope of the per-pass high-water samples, bytes/s
    pub high_water_slope_bps: f64,
    /// most recent high-water sample, bytes
    pub high_water_last: u64,
    pub decode_steps_per_s: f64,
    pub retires_per_s: f64,
    pub sheds_per_s: f64,
    pub events_seen: u64,
    /// events this aggregator's own ring dropped (consumer too slow)
    pub subscriber_dropped: u64,
    /// events the bus shards dropped (ring full at the emitters)
    pub bus_dropped: u64,
}

impl SignalSnapshot {
    pub fn to_json(&self) -> Value {
        let mut lanes = Vec::with_capacity(self.lanes.len());
        for l in &self.lanes {
            lanes.push(
                Value::obj()
                    .set("lane", l.lane as u64)
                    .set("stall_mem_ms", l.stall_mem_ms)
                    .set("stall_wait_ms", l.stall_wait_ms)
                    .set("compute_ms", l.compute_ms)
                    .set("stall_mem_ratio", l.stall_mem_ratio())
                    .set("stall_wait_ratio", l.stall_wait_ratio()),
            );
        }
        let mut shed = Value::obj();
        for (r, n) in &self.shed_by_reason {
            shed = shed.set(r, *n);
        }
        Value::obj()
            .set("enabled", self.enabled)
            .set("window_s", self.window_s)
            .set("lanes", Value::Arr(lanes))
            .set("shed_by_reason", shed)
            .set("sheds_per_s", self.sheds_per_s)
            .set("prefetch_bytes_per_s", self.prefetch_bytes_per_s)
            .set("waste_bytes_per_s", self.waste_bytes_per_s)
            .set("waste_ratio", self.waste_ratio)
            .set("high_water_slope_bps", self.high_water_slope_bps)
            .set("high_water_last", self.high_water_last)
            .set("decode_steps_per_s", self.decode_steps_per_s)
            .set("retires_per_s", self.retires_per_s)
            .set("events_seen", self.events_seen)
            .set("subscriber_dropped", self.subscriber_dropped)
            .set("bus_dropped", self.bus_dropped)
    }

    /// Append the derived gauges to a Prometheus exposition (the
    /// `{"op":"metrics"}` text already carries the summary counters).
    pub fn to_prometheus(&self, out: &mut String) {
        out.push_str(
            "# HELP hermes_lane_stall_ratio share of a lane's observed worker time in a stall state over the health window\n# TYPE hermes_lane_stall_ratio gauge\n",
        );
        for l in &self.lanes {
            out.push_str(&format!(
                "hermes_lane_stall_ratio{{lane=\"{}\",kind=\"mem\"}} {:.6}\n",
                l.lane,
                l.stall_mem_ratio()
            ));
            out.push_str(&format!(
                "hermes_lane_stall_ratio{{lane=\"{}\",kind=\"wait\"}} {:.6}\n",
                l.lane,
                l.stall_wait_ratio()
            ));
        }
        prometheus_gauge(
            out,
            "hermes_shed_rate",
            "requests shed per second over the health window",
            self.sheds_per_s,
        );
        prometheus_gauge(
            out,
            "hermes_prefetch_waste_bytes_per_s",
            "speculative bytes reclaimed or discarded per second",
            self.waste_bytes_per_s,
        );
        prometheus_gauge(
            out,
            "hermes_prefetch_waste_ratio",
            "wasted / prefetched bytes over the health window",
            self.waste_ratio,
        );
        prometheus_gauge(
            out,
            "hermes_high_water_slope_bps",
            "trend of the accountant per-pass peak, bytes per second",
            self.high_water_slope_bps,
        );
        prometheus_gauge(
            out,
            "hermes_decode_steps_per_s",
            "token decode steps per second over the health window",
            self.decode_steps_per_s,
        );
        prometheus_gauge(
            out,
            "hermes_retire_rate",
            "requests retired per second over the health window",
            self.retires_per_s,
        );
        prometheus_counter(
            out,
            "hermes_health_subscriber_dropped_total",
            "events the health aggregator's own ring dropped",
            self.subscriber_dropped,
        );
    }
}

/// The live aggregator: one bounded subscription + a windowed fold.
pub struct DerivedSignals {
    telemetry: Telemetry,
    sub: Subscription,
    window_us: u64,
    state: Mutex<State>,
}

impl DerivedSignals {
    /// Subscribe to `telemetry` and aggregate over `window`.  Cheap on a
    /// disabled bus: nothing is ever emitted, so nothing is ever folded.
    pub fn attach(telemetry: &Telemetry, window: Duration) -> DerivedSignals {
        DerivedSignals {
            sub: telemetry.subscribe("derived-signals", SUB_CAP),
            telemetry: telemetry.clone(),
            window_us: (window.as_micros() as u64).max(1),
            state: Mutex::new(State::default()),
        }
    }

    /// Drain the subscription and return the current window's view.
    pub fn poll(&self) -> SignalSnapshot {
        let events = self.sub.drain();
        self.ingest(events, self.telemetry.now_us())
    }

    fn ingest(&self, events: Vec<Event>, now_us: u64) -> SignalSnapshot {
        let mut st = self.state.lock().unwrap();
        for ev in events {
            st.events_seen += 1;
            if let Some(s) = classify(&ev) {
                if let Sample::HighWater { bytes } = s {
                    st.high_water_last = bytes as u64;
                }
                // key by span END so a long stall leaves the window only
                // after it actually stopped stalling
                st.samples.push_back((ev.ts_us + ev.dur_us, s));
            }
        }
        let cutoff = now_us.saturating_sub(self.window_us);
        while st.samples.front().is_some_and(|(t, _)| *t < cutoff) {
            st.samples.pop_front();
        }
        let window_s = (now_us.saturating_sub(cutoff)).max(1) as f64 / 1e6;

        let mut lanes: BTreeMap<u32, LaneSignals> = BTreeMap::new();
        let mut shed_by_reason: BTreeMap<String, u64> = BTreeMap::new();
        let mut prefetch_bytes = 0u64;
        let mut waste_bytes = 0u64;
        let mut high_water: Vec<(f64, f64)> = Vec::new();
        let (mut decode_steps, mut retires, mut sheds) = (0u64, 0u64, 0u64);
        for (ts, s) in &st.samples {
            match s {
                Sample::StallMem { lane, ms } => {
                    let l = lanes.entry(*lane).or_insert(LaneSignals { lane: *lane, ..Default::default() });
                    l.stall_mem_ms += ms;
                }
                Sample::StallWait { lane, ms } => {
                    let l = lanes.entry(*lane).or_insert(LaneSignals { lane: *lane, ..Default::default() });
                    l.stall_wait_ms += ms;
                }
                Sample::Compute { lane, ms } => {
                    let l = lanes.entry(*lane).or_insert(LaneSignals { lane: *lane, ..Default::default() });
                    l.compute_ms += ms;
                }
                Sample::Shed { reason } => {
                    *shed_by_reason.entry(reason.clone()).or_default() += 1;
                    sheds += 1;
                }
                Sample::Prefetch { bytes } => prefetch_bytes += bytes,
                Sample::Waste { bytes } => waste_bytes += bytes,
                Sample::HighWater { bytes } => high_water.push((*ts as f64 / 1e6, *bytes)),
                Sample::DecodeStep => decode_steps += 1,
                Sample::Retire => retires += 1,
            }
        }
        SignalSnapshot {
            window_s,
            enabled: self.telemetry.is_on(),
            lanes: lanes.into_values().collect(),
            shed_by_reason,
            prefetch_bytes_per_s: prefetch_bytes as f64 / window_s,
            waste_bytes_per_s: waste_bytes as f64 / window_s,
            waste_ratio: if prefetch_bytes == 0 {
                0.0
            } else {
                waste_bytes as f64 / prefetch_bytes as f64
            },
            high_water_slope_bps: least_squares_slope(&high_water),
            high_water_last: st.high_water_last,
            decode_steps_per_s: decode_steps as f64 / window_s,
            retires_per_s: retires as f64 / window_s,
            sheds_per_s: sheds as f64 / window_s,
            events_seen: st.events_seen,
            subscriber_dropped: self.sub.dropped(),
            bus_dropped: self.telemetry.dropped(),
        }
    }
}

/// Ordinary least-squares slope of (seconds, bytes) points; 0 with
/// fewer than two distinct sample times.
fn least_squares_slope(points: &[(f64, f64)]) -> f64 {
    if points.len() < 2 {
        return 0.0;
    }
    let n = points.len() as f64;
    let mean_x = points.iter().map(|(x, _)| x).sum::<f64>() / n;
    let mean_y = points.iter().map(|(_, y)| y).sum::<f64>() / n;
    let var: f64 = points.iter().map(|(x, _)| (x - mean_x) * (x - mean_x)).sum();
    if var <= 0.0 {
        return 0.0;
    }
    let cov: f64 = points.iter().map(|(x, y)| (x - mean_x) * (y - mean_y)).sum();
    cov / var
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{worker, EvArgs};

    fn span(name: &'static str, lane: u32, ts: u64, dur: u64) -> Event {
        Event {
            name,
            phase: Phase::Complete,
            lane,
            worker: worker::INFER,
            ts_us: ts,
            dur_us: dur,
            args: EvArgs::default(),
        }
    }

    fn instant(name: &'static str, ts: u64, args: EvArgs) -> Event {
        Event { name, phase: Phase::Instant, lane: 0, worker: worker::DRIVER, ts_us: ts, dur_us: 0, args }
    }

    fn counter(name: &'static str, ts: u64, value: f64) -> Event {
        Event {
            name,
            phase: Phase::Counter,
            lane: 0,
            worker: worker::DRIVER,
            ts_us: ts,
            dur_us: 0,
            args: EvArgs { value: Some(value), ..EvArgs::default() },
        }
    }

    #[test]
    fn lane_ratios_and_rates_from_synthetic_window() {
        let t = Telemetry::on();
        let d = DerivedSignals::attach(&t, Duration::from_secs(10));
        let evs = vec![
            span("compute", 0, 0, 3_000),
            span("stall_wait", 0, 3_000, 1_000),
            span("stall_mem", 1, 0, 2_000),
            span("compute", 1, 2_000, 2_000),
            instant("shed", 100, EvArgs::req(9).with_reason("shed_overload")),
            instant("decode_step", 200, EvArgs::req(1)),
            instant("decode_step", 300, EvArgs::req(1)),
            instant("retire", 400, EvArgs::req(1)),
            instant("prefetch_waste", 500, EvArgs::default().with_bytes(500).with_reason("evicted")),
            Event { args: EvArgs::default().with_bytes(1000), ..span("prefetch", 0, 0, 100) },
        ];
        let s = d.ingest(evs, 1_000_000); // 1s into the bus clock
        assert!(s.enabled);
        assert_eq!(s.lanes.len(), 2);
        let l0 = s.lanes.iter().find(|l| l.lane == 0).unwrap();
        assert!((l0.stall_wait_ratio() - 0.25).abs() < 1e-9);
        assert!((l0.stall_mem_ratio() - 0.0).abs() < 1e-9);
        let l1 = s.lanes.iter().find(|l| l.lane == 1).unwrap();
        assert!((l1.stall_mem_ratio() - 0.5).abs() < 1e-9);
        assert_eq!(s.shed_by_reason.get("shed_overload"), Some(&1));
        // 1s effective window: rates are per-second counts
        assert!((s.decode_steps_per_s - 2.0).abs() < 1e-6);
        assert!((s.retires_per_s - 1.0).abs() < 1e-6);
        assert!((s.sheds_per_s - 1.0).abs() < 1e-6);
        assert!((s.waste_ratio - 0.5).abs() < 1e-9);
        assert_eq!(s.events_seen, 10);
    }

    #[test]
    fn window_evicts_old_samples() {
        let t = Telemetry::on();
        let d = DerivedSignals::attach(&t, Duration::from_secs(1));
        d.ingest(vec![instant("retire", 0, EvArgs::req(1))], 500_000);
        // 2s later the retire is outside the 1s window
        let s = d.ingest(vec![instant("retire", 2_400_000, EvArgs::req(2))], 2_500_000);
        assert!((s.retires_per_s - 1.0).abs() < 1e-6, "only the recent retire remains");
        assert_eq!(s.events_seen, 2, "seen-counter is cumulative");
    }

    #[test]
    fn high_water_slope_tracks_growth() {
        let t = Telemetry::on();
        let d = DerivedSignals::attach(&t, Duration::from_secs(10));
        let evs = vec![
            counter("mem_high_water", 0, 1_000.0),
            counter("mem_high_water", 500_000, 2_000.0),
            counter("mem_high_water", 1_000_000, 3_000.0),
        ];
        let s = d.ingest(evs, 1_000_000);
        // +1000 bytes every 0.5 s -> 2000 bytes/s
        assert!((s.high_water_slope_bps - 2000.0).abs() < 1e-6, "{}", s.high_water_slope_bps);
        assert_eq!(s.high_water_last, 3_000);
        // flat series -> zero slope
        let d2 = DerivedSignals::attach(&t, Duration::from_secs(10));
        let s2 = d2.ingest(
            vec![counter("mem_high_water", 0, 5.0), counter("mem_high_water", 100, 5.0)],
            1_000,
        );
        assert!((s2.high_water_slope_bps - 0.0).abs() < 1e-9);
    }

    #[test]
    fn live_subscription_feeds_poll() {
        let t = Telemetry::on();
        let d = DerivedSignals::attach(&t, DEFAULT_WINDOW);
        t.instant("retire", worker::DRIVER, EvArgs::req(1));
        t.instant("shed", worker::DRIVER, EvArgs::req(2).with_reason("shed_queue_full"));
        let s = d.poll();
        assert_eq!(s.events_seen, 2);
        assert_eq!(s.shed_by_reason.get("shed_queue_full"), Some(&1));
        assert_eq!(s.subscriber_dropped, 0);
        assert_eq!(s.bus_dropped, 0);
        // json + prometheus render
        let j = s.to_json();
        assert!(j.get("enabled").unwrap().as_bool().unwrap());
        let mut text = String::new();
        s.to_prometheus(&mut text);
        assert!(text.contains("hermes_shed_rate"));
        assert!(text.contains("hermes_high_water_slope_bps"));
    }

    #[test]
    fn disabled_bus_snapshot_is_inert() {
        let t = Telemetry::off();
        let d = DerivedSignals::attach(&t, DEFAULT_WINDOW);
        t.instant("retire", worker::DRIVER, EvArgs::req(1)); // no-op: bus off
        let s = d.poll();
        assert!(!s.enabled);
        assert_eq!(s.events_seen, 0);
        assert!(s.lanes.is_empty());
    }
}
