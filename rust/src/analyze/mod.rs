//! Trace analytics: offline lifecycle reconstruction + derived signals.
//!
//! Consumes the telemetry bus (PR: unified telemetry) in two forms and
//! derives what the raw event stream only implies:
//!
//! * **Offline** — [`Analysis`] rebuilds every request's lifecycle
//!   (enqueue → admit/shed → prime → per-token decode → retire), splits
//!   each pass's wall-clock into compute / pipeline-bubble / residual
//!   along the inference row (critical-path attribution, per stage), and
//!   re-checks the memory-attribution audit: every `mem_audit` sample
//!   carries both the accountant's `used` and the sum of the component
//!   stores (pins + device + prefetch + KV + pass-live + resident), so
//!   nonzero drift means a byte the accountant holds that no store owns
//!   up to — reported as an **error**, never smoothed over.  Feeds
//!   `hermes analyze` and `hermes report --figure 1b` (one code path).
//! * **Live** — [`signals::DerivedSignals`] subscribes to the bus
//!   ([`Telemetry::subscribe`]) and keeps rolling-window rates (stall
//!   ratios per lane, shed rate by reason, prefetch waste rate,
//!   accountant high-water slope) behind the `{"op":"health"}` TCP op —
//!   the in-process hook a closed-loop elastic controller attaches to.
//!
//! A trace that cannot be fully reconstructed — dropped events, a
//! request admitted but never retired, an unclosed pass span — fails
//! loudly: [`Analysis::errors`] is non-empty and [`Analysis::ok`] is
//! false.  Partial numbers from a truncated trace are worse than no
//! numbers.
//!
//! [`Telemetry::subscribe`]: crate::telemetry::Telemetry::subscribe

pub mod signals;

pub use signals::{DerivedSignals, LaneSignals, SignalSnapshot, DEFAULT_WINDOW};

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::metrics::LatencyRecorder;
use crate::telemetry::{worker, Event, Phase};
use crate::util::json::Value;
use crate::util::{human_bytes, human_ms};

/// One telemetry event in owned form: what [`Event`] carries, but with
/// an owned name/reason so events parsed back out of a Chrome trace
/// file and events drained straight off the bus analyze identically.
#[derive(Debug, Clone)]
pub struct AnEvent {
    pub name: String,
    pub phase: Phase,
    pub lane: u32,
    pub worker: u32,
    pub ts_us: u64,
    pub dur_us: u64,
    pub pass: Option<u64>,
    pub stage: Option<usize>,
    pub req: Option<u64>,
    pub bytes: Option<u64>,
    pub reason: Option<String>,
    pub value: Option<f64>,
}

impl AnEvent {
    fn from_bus(ev: &Event) -> AnEvent {
        AnEvent {
            name: ev.name.to_string(),
            phase: ev.phase,
            lane: ev.lane,
            worker: ev.worker,
            ts_us: ev.ts_us,
            dur_us: ev.dur_us,
            pass: ev.args.pass,
            stage: ev.args.stage,
            req: ev.args.req,
            bytes: ev.args.bytes,
            reason: ev.args.reason.map(str::to_string),
            value: ev.args.value,
        }
    }
}

/// Parse a Chrome trace document (the exact shape
/// [`crate::telemetry::chrome::chrome_trace`] writes) back into owned
/// events + the recorded drop count.  Structural problems — missing
/// keys, unknown phases — are hard errors: an unreadable trace must not
/// analyze as an empty (healthy-looking) one.
pub fn events_from_chrome(doc: &Value) -> Result<(Vec<AnEvent>, u64)> {
    let raw = doc
        .get("traceEvents")
        .context("not a Chrome trace: missing traceEvents")?
        .as_arr()
        .context("traceEvents is not an array")?;
    let dropped = doc
        .get("otherData")
        .and_then(|o| o.get("dropped_events"))
        .and_then(|d| d.as_i64().ok())
        .unwrap_or(0)
        .max(0) as u64;
    let mut out = Vec::with_capacity(raw.len());
    for (i, ev) in raw.iter().enumerate() {
        let ph = ev.get("ph").with_context(|| format!("event {i}: missing ph"))?.as_str()?;
        if ph == "M" {
            continue; // synthesized metadata rows carry no measurements
        }
        let phase = match ph {
            "B" => Phase::Begin,
            "E" => Phase::End,
            "i" => Phase::Instant,
            "X" => Phase::Complete,
            "C" => Phase::Counter,
            other => bail!("event {i}: unknown phase '{other}'"),
        };
        let name =
            ev.get("name").with_context(|| format!("event {i}: missing name"))?.as_str()?;
        let args = ev.get("args");
        let get_u64 = |key: &str| -> Option<u64> {
            args.and_then(|a| a.get(key)).and_then(|v| v.as_i64().ok()).map(|v| v.max(0) as u64)
        };
        out.push(AnEvent {
            name: name.to_string(),
            phase,
            lane: ev.get("pid").with_context(|| format!("event {i}: missing pid"))?.as_f64()?
                as u32,
            worker: ev.get("tid").with_context(|| format!("event {i}: missing tid"))?.as_f64()?
                as u32,
            ts_us: ev.get("ts").with_context(|| format!("event {i}: missing ts"))?.as_f64()?
                .max(0.0) as u64,
            dur_us: ev.get("dur").and_then(|d| d.as_f64().ok()).unwrap_or(0.0).max(0.0) as u64,
            pass: get_u64("pass"),
            stage: args
                .and_then(|a| a.get("stage"))
                .and_then(|v| v.as_usize().ok()),
            req: get_u64("req"),
            bytes: get_u64("bytes"),
            reason: args
                .and_then(|a| a.get("reason"))
                .and_then(|v| v.as_str().ok())
                .map(str::to_string),
            value: args.and_then(|a| a.get("value")).and_then(|v| v.as_f64().ok()),
        });
    }
    Ok((out, dropped))
}

/// One reconstructed request lifecycle.
#[derive(Debug, Clone)]
pub struct RequestBreakdown {
    pub id: u64,
    pub lane: u32,
    /// `served` | `shed` | `failed`
    pub outcome: &'static str,
    /// shed cause or failure cause, when one was recorded
    pub reason: Option<String>,
    /// enqueue → admission (or → shed decision)
    pub queue_ms: f64,
    /// prime → join (continuous lanes; 0 elsewhere)
    pub prime_ms: f64,
    pub decode_steps: u64,
    /// admission → retire (0 for shed requests)
    pub service_ms: f64,
    /// enqueue → final lifecycle edge
    pub total_ms: f64,
}

/// One pass window's critical-path split.  By construction
/// `compute_ms + bubble_ms + residual_ms == dur_ms`: the inference row
/// inside a pass is strictly sequential, so every microsecond is either
/// computing, waiting on a loader (`stall_wait` — the pipeline bubble),
/// or driver-side residue (dispatch, token bookkeeping, admission).
#[derive(Debug, Clone, Default)]
pub struct PassBreakdown {
    pub lane: u32,
    pub pass: u64,
    pub start_us: u64,
    pub dur_ms: f64,
    pub compute_ms: f64,
    /// inference-row wait time, the exposed (non-overlapped) load
    pub bubble_ms: f64,
    /// loader-row admission stalls (`S^stop` pressure) inside the window
    pub stall_mem_ms: f64,
    /// loader-row disk time inside the window (overlapped where the
    /// pipeline works; exposed as `bubble_ms` where it does not)
    pub load_ms: f64,
    pub residual_ms: f64,
    pub bubble_by_stage: BTreeMap<usize, f64>,
}

/// Memory-attribution audit over every self-contained `mem_audit`
/// sample (value = accountant `used`, bytes = sum of component stores).
#[derive(Debug, Clone, Default)]
pub struct MemAudit {
    pub samples: usize,
    /// largest |used − components| over all samples; nonzero is an error
    pub max_drift_bytes: i64,
    /// largest accountant `used` seen at a settled sample point
    pub settled_used_max: u64,
    /// largest per-pass peak (`mem_high_water` counter)
    pub high_water_max: u64,
}

impl MemAudit {
    pub fn ok(&self) -> bool {
        self.max_drift_bytes == 0
    }
}

/// Speculation that was paid for and thrown away.
#[derive(Debug, Clone, Default)]
pub struct PrefetchWasteSummary {
    pub events: usize,
    pub bytes: u64,
    /// cause → (events, bytes); causes today: `evicted` (reclaimed under
    /// pressure before use), `stale_duplicate` (the pass loaded it first)
    pub by_reason: BTreeMap<String, (usize, u64)>,
}

/// Whole-trace span totals, window-independent — these are what must
/// reconcile with `RunReport` / `RouterSummary` counters.
#[derive(Debug, Clone, Default)]
pub struct Totals {
    pub compute_ms: f64,
    pub stall_wait_ms: f64,
    pub stall_mem_ms: f64,
    pub load_ms: f64,
    pub prefetch_ms: f64,
}

/// The reconstructed trace: requests, passes, audit, totals, and every
/// reconstruction failure in [`Analysis::errors`].
pub struct Analysis {
    pub requests: Vec<RequestBreakdown>,
    pub passes: Vec<PassBreakdown>,
    /// stage → inference-row bubble attributed to waiting on that stage
    pub bubble_by_stage: BTreeMap<usize, f64>,
    pub totals: Totals,
    pub audit: MemAudit,
    pub waste: PrefetchWasteSummary,
    /// admission wait of every admitted request
    pub queue_wait: LatencyRecorder,
    /// enqueue → retire of every served request
    pub total_latency: LatencyRecorder,
    pub decode_steps: u64,
    pub batches: u64,
    pub dropped_events: u64,
    pub errors: Vec<String>,
    pub notes: Vec<String>,
    events: Vec<AnEvent>,
}

#[derive(Default)]
struct ReqState {
    lane: u32,
    enqueue: Option<u64>,
    admit: Option<u64>,
    shed: Option<(u64, Option<String>)>,
    prime: Option<u64>,
    join: Option<u64>,
    decode_steps: u64,
    retire: Option<(u64, Option<String>)>,
    leave: Option<u64>,
}

fn ms(us: u64) -> f64 {
    us as f64 / 1000.0
}

impl Analysis {
    /// Analyze events drained straight off a live bus.
    pub fn from_bus(events: &[Event], dropped: u64) -> Analysis {
        Analysis::from_events(events.iter().map(AnEvent::from_bus).collect(), dropped)
    }

    /// Analyze a parsed Chrome trace document.
    pub fn from_chrome(doc: &Value) -> Result<Analysis> {
        let (events, dropped) = events_from_chrome(doc)?;
        Ok(Analysis::from_events(events, dropped))
    }

    /// Analyze a Chrome trace file (`hermes analyze <trace.json>`).
    pub fn from_file(path: &Path) -> Result<Analysis> {
        let doc = Value::from_file(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Analysis::from_chrome(&doc)
    }

    /// The full reconstruction.  Never panics on malformed input — every
    /// inconsistency lands in `errors` instead, so a truncated trace
    /// produces a loud report, not a quiet half-answer.
    pub fn from_events(mut events: Vec<AnEvent>, dropped: u64) -> Analysis {
        events.sort_by_key(|e| (e.ts_us, e.lane, e.worker));
        let mut errors = Vec::new();
        let mut notes = Vec::new();
        if dropped > 0 {
            errors.push(format!(
                "trace is incomplete: {dropped} event(s) dropped at the bus (ring full) — \
                 lifecycle and attribution cannot be trusted"
            ));
        }

        // ---- pass/batch windows via per-(lane, worker) B/E stacks ----
        struct Window {
            lane: u32,
            pass: u64,
            t0: u64,
            t1: u64,
        }
        let mut stacks: BTreeMap<(u32, u32), Vec<(String, u64, Option<u64>)>> = BTreeMap::new();
        let mut windows: Vec<Window> = Vec::new();
        let mut batches = 0u64;
        let mut reqs: BTreeMap<u64, ReqState> = BTreeMap::new();
        let mut totals = Totals::default();
        let mut audit = MemAudit::default();
        let mut waste = PrefetchWasteSummary::default();
        let mut decode_steps_total = 0u64;
        let mut pass_seq = 0u64;

        for ev in &events {
            match ev.phase {
                Phase::Begin => {
                    stacks
                        .entry((ev.lane, ev.worker))
                        .or_default()
                        .push((ev.name.clone(), ev.ts_us, ev.pass));
                }
                Phase::End => {
                    let stack = stacks.entry((ev.lane, ev.worker)).or_default();
                    match stack.pop() {
                        None => errors.push(format!(
                            "lane {} worker {}: '{}' ends a span that never began",
                            ev.lane, ev.worker, ev.name
                        )),
                        Some((open, t0, pass)) => {
                            if open != ev.name {
                                errors.push(format!(
                                    "lane {} worker {}: '{}' closes open span '{open}'",
                                    ev.lane, ev.worker, ev.name
                                ));
                            } else if ev.name == "pass" {
                                let pass = pass.unwrap_or(pass_seq);
                                pass_seq = pass + 1;
                                windows.push(Window { lane: ev.lane, pass, t0, t1: ev.ts_us });
                            } else if ev.name == "batch" {
                                batches += 1;
                            }
                        }
                    }
                }
                Phase::Complete => match ev.name.as_str() {
                    "compute" => totals.compute_ms += ms(ev.dur_us),
                    "stall_wait" => totals.stall_wait_ms += ms(ev.dur_us),
                    "stall_mem" => totals.stall_mem_ms += ms(ev.dur_us),
                    "load" => totals.load_ms += ms(ev.dur_us),
                    "prefetch" => totals.prefetch_ms += ms(ev.dur_us),
                    _ => {}
                },
                Phase::Instant => {
                    if ev.name == "prefetch_waste" {
                        let b = ev.bytes.unwrap_or(0);
                        waste.events += 1;
                        waste.bytes += b;
                        let r = waste
                            .by_reason
                            .entry(ev.reason.clone().unwrap_or_else(|| "unknown".into()))
                            .or_default();
                        r.0 += 1;
                        r.1 += b;
                    } else if let Some(id) = ev.req {
                        let r = reqs.entry(id).or_default();
                        match ev.name.as_str() {
                            "enqueue" => {
                                r.lane = ev.lane;
                                r.enqueue = Some(ev.ts_us);
                            }
                            "admit" => {
                                r.lane = ev.lane;
                                r.admit = Some(ev.ts_us);
                            }
                            "shed" => r.shed = Some((ev.ts_us, ev.reason.clone())),
                            "prime" => r.prime = Some(ev.ts_us),
                            "join" => r.join = Some(ev.ts_us),
                            "decode_step" => {
                                r.decode_steps += 1;
                                decode_steps_total += 1;
                            }
                            "retire" => r.retire = Some((ev.ts_us, ev.reason.clone())),
                            "leave" => r.leave = Some(ev.ts_us),
                            _ => {}
                        }
                    }
                }
                Phase::Counter => match ev.name.as_str() {
                    "mem_audit" => match (ev.value, ev.bytes) {
                        (Some(used), Some(components)) => {
                            let used = used.max(0.0) as u64;
                            let drift = used as i64 - components as i64;
                            audit.samples += 1;
                            if drift.abs() > audit.max_drift_bytes.abs() {
                                audit.max_drift_bytes = drift;
                            }
                            audit.settled_used_max = audit.settled_used_max.max(used);
                        }
                        _ => errors.push(format!(
                            "mem_audit sample at {}us is missing value/bytes",
                            ev.ts_us
                        )),
                    },
                    "mem_high_water" => {
                        audit.high_water_max = audit
                            .high_water_max
                            .max(ev.value.unwrap_or(0.0).max(0.0) as u64);
                    }
                    _ => {}
                },
            }
        }

        for ((lane, worker_id), stack) in &stacks {
            for (name, _, _) in stack {
                errors.push(format!(
                    "lane {lane} worker {worker_id}: span '{name}' never closed (truncated trace?)"
                ));
            }
        }

        // ---- memory audit verdicts ----
        if audit.samples == 0 {
            notes.push(
                "no mem_audit samples (concurrent lanes, or telemetry attached mid-run): \
                 memory attribution not checkable"
                    .to_string(),
            );
        } else if !audit.ok() {
            errors.push(format!(
                "memory audit drift: accountant used differs from component sum by up to {} \
                 bytes across {} sample(s) — some accounted bytes have no owning store",
                audit.max_drift_bytes, audit.samples
            ));
        }
        if audit.high_water_max > 0 && audit.settled_used_max > audit.high_water_max {
            errors.push(format!(
                "settled used {} exceeds the high-water peak {} — counter streams disagree",
                human_bytes(audit.settled_used_max),
                human_bytes(audit.high_water_max)
            ));
        }

        // ---- per-pass critical-path attribution ----
        windows.sort_by_key(|w| (w.lane, w.t0));
        let mut lane_windows: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
        for (i, w) in windows.iter().enumerate() {
            lane_windows.entry(w.lane).or_default().push(i);
        }
        let find_window = |lane: u32, ts: u64| -> Option<usize> {
            let idxs = lane_windows.get(&lane)?;
            // last window starting at or before ts (windows on one lane
            // are disjoint: the driver row emits passes sequentially)
            let pos = idxs.partition_point(|&i| windows[i].t0 <= ts);
            if pos == 0 {
                return None;
            }
            let i = idxs[pos - 1];
            (ts < windows[i].t1).then_some(i)
        };
        let mut per_pass: BTreeMap<usize, PassBreakdown> = BTreeMap::new();
        let mut unattributed = 0usize;
        for ev in &events {
            if ev.phase != Phase::Complete {
                continue;
            }
            let Some(wi) = find_window(ev.lane, ev.ts_us) else {
                if matches!(ev.name.as_str(), "compute" | "stall_wait" | "stall_mem" | "load") {
                    unattributed += 1;
                }
                continue;
            };
            let p = per_pass.entry(wi).or_default();
            match ev.name.as_str() {
                "compute" => p.compute_ms += ms(ev.dur_us),
                "stall_wait" => {
                    p.bubble_ms += ms(ev.dur_us);
                    if let Some(s) = ev.stage {
                        *p.bubble_by_stage.entry(s).or_default() += ms(ev.dur_us);
                    }
                }
                "stall_mem" => p.stall_mem_ms += ms(ev.dur_us),
                "load" => p.load_ms += ms(ev.dur_us),
                _ => {}
            }
        }
        if unattributed > 0 {
            notes.push(format!(
                "{unattributed} worker span(s) fell outside every pass window \
                 (cross-pass prefetch and boundary jitter land here)"
            ));
        }
        let mut passes: Vec<PassBreakdown> = Vec::with_capacity(windows.len());
        let mut bubble_by_stage: BTreeMap<usize, f64> = BTreeMap::new();
        for (i, w) in windows.iter().enumerate() {
            let mut p = per_pass.remove(&i).unwrap_or_default();
            p.lane = w.lane;
            p.pass = w.pass;
            p.start_us = w.t0;
            p.dur_ms = ms(w.t1.saturating_sub(w.t0));
            p.residual_ms = p.dur_ms - p.compute_ms - p.bubble_ms;
            for (s, b) in &p.bubble_by_stage {
                *bubble_by_stage.entry(*s).or_default() += *b;
            }
            passes.push(p);
        }

        // ---- request lifecycles ----
        let mut requests = Vec::with_capacity(reqs.len());
        let mut queue_wait = LatencyRecorder::new();
        let mut total_latency = LatencyRecorder::new();
        for (id, r) in &reqs {
            let Some(enq) = r.enqueue else {
                errors.push(format!("req {id}: lifecycle events without an enqueue"));
                continue;
            };
            match (&r.admit, &r.shed) {
                (Some(_), Some(_)) => {
                    errors.push(format!("req {id}: both admitted and shed"));
                    continue;
                }
                (None, None) => {
                    errors.push(format!(
                        "req {id}: enqueued but neither admitted nor shed (truncated trace?)"
                    ));
                    continue;
                }
                _ => {}
            }
            if r.prime.is_some() && r.join.is_none() {
                errors.push(format!("req {id}: primed but never joined the decode"));
            }
            if r.join.is_some() && r.leave.is_none() {
                errors.push(format!("req {id}: joined the decode but never left"));
            }
            if r.decode_steps > 0 && r.join.is_none() {
                errors.push(format!("req {id}: decode steps recorded before any join"));
            }
            if let Some((shed_ts, reason)) = &r.shed {
                requests.push(RequestBreakdown {
                    id: *id,
                    lane: r.lane,
                    outcome: "shed",
                    reason: reason.clone(),
                    queue_ms: ms(shed_ts.saturating_sub(enq)),
                    prime_ms: 0.0,
                    decode_steps: r.decode_steps,
                    service_ms: 0.0,
                    total_ms: ms(shed_ts.saturating_sub(enq)),
                });
                continue;
            }
            let admit = r.admit.unwrap(); // shed xor admit checked above
            let Some((retire_ts, retire_reason)) = &r.retire else {
                errors.push(format!("req {id}: admitted but never retired (truncated trace?)"));
                continue;
            };
            let queue_ms = ms(admit.saturating_sub(enq));
            queue_wait.record_ms(queue_ms);
            let end = r.leave.unwrap_or(*retire_ts).max(*retire_ts);
            let served = retire_reason.is_none();
            if served {
                total_latency.record_ms(ms(end.saturating_sub(enq)));
            }
            requests.push(RequestBreakdown {
                id: *id,
                lane: r.lane,
                outcome: if served { "served" } else { "failed" },
                reason: retire_reason.clone(),
                queue_ms,
                prime_ms: match (r.prime, r.join) {
                    (Some(p), Some(j)) => ms(j.saturating_sub(p)),
                    _ => 0.0,
                },
                decode_steps: r.decode_steps,
                service_ms: ms(retire_ts.saturating_sub(admit)),
                total_ms: ms(end.saturating_sub(enq)),
            });
        }

        Analysis {
            requests,
            passes,
            bubble_by_stage,
            totals,
            audit,
            waste,
            queue_wait,
            total_latency,
            decode_steps: decode_steps_total,
            batches,
            dropped_events: dropped,
            errors,
            notes,
            events,
        }
    }

    /// True when the trace reconstructed cleanly: complete lifecycles,
    /// balanced spans, zero audit drift, zero dropped events.
    pub fn ok(&self) -> bool {
        self.errors.is_empty()
    }

    pub fn served(&self) -> usize {
        self.requests.iter().filter(|r| r.outcome == "served").count()
    }

    pub fn shed(&self) -> usize {
        self.requests.iter().filter(|r| r.outcome == "shed").count()
    }

    pub fn failed(&self) -> usize {
        self.requests.iter().filter(|r| r.outcome == "failed").count()
    }

    /// Total inference-row bubble across every pass window.
    pub fn bubble_total_ms(&self) -> f64 {
        self.passes.iter().map(|p| p.bubble_ms).sum()
    }

    /// Fraction of the inference rows' active window spent NOT computing
    /// (the figure-1b headline number), across all lanes.
    pub fn inference_idle_fraction(&self) -> Option<f64> {
        let spans: Vec<&AnEvent> = self
            .events
            .iter()
            .filter(|e| e.phase == Phase::Complete && e.worker == worker::INFER)
            .collect();
        let first = spans.iter().map(|e| e.ts_us).min()?;
        let last = spans.iter().map(|e| e.ts_us + e.dur_us).max()?;
        let window = (last - first) as f64;
        if window <= 0.0 {
            return None;
        }
        let busy: f64 = spans
            .iter()
            .filter(|e| e.name == "compute")
            .map(|e| e.dur_us as f64)
            .sum();
        Some((1.0 - busy / window).clamp(0.0, 1.0))
    }

    /// Machine-readable summary (the `hermes analyze --json` payload and
    /// the benchmark's `analyze` section).
    pub fn to_json(&self) -> Value {
        let mut stage_obj = Value::obj();
        for (s, b) in &self.bubble_by_stage {
            stage_obj = stage_obj.set(&format!("{s}"), *b);
        }
        let mut reason_obj = Value::obj();
        for (r, (n, b)) in &self.waste.by_reason {
            reason_obj = reason_obj.set(r, Value::obj().set("events", *n).set("bytes", *b));
        }
        let pass_wall: f64 = self.passes.iter().map(|p| p.dur_ms).sum();
        Value::obj()
            .set("ok", self.ok())
            .set(
                "errors",
                Value::Arr(self.errors.iter().map(|e| Value::from(e.as_str())).collect()),
            )
            .set(
                "notes",
                Value::Arr(self.notes.iter().map(|n| Value::from(n.as_str())).collect()),
            )
            .set("dropped_events", self.dropped_events)
            .set(
                "requests",
                Value::obj()
                    .set("total", self.requests.len())
                    .set("served", self.served())
                    .set("shed", self.shed())
                    .set("failed", self.failed())
                    .set("decode_steps", self.decode_steps)
                    .set(
                        "queue_wait_ms",
                        Value::obj()
                            .set("p50", self.queue_wait.p50())
                            .set("p95", self.queue_wait.p95())
                            .set("mean", self.queue_wait.mean()),
                    )
                    .set(
                        "latency_ms",
                        Value::obj()
                            .set("p50", self.total_latency.p50())
                            .set("p95", self.total_latency.p95())
                            .set("mean", self.total_latency.mean()),
                    ),
            )
            .set(
                "passes",
                Value::obj()
                    .set("count", self.passes.len())
                    .set("batches", self.batches)
                    .set("wall_ms", pass_wall)
                    .set("compute_ms", self.passes.iter().map(|p| p.compute_ms).sum::<f64>())
                    .set("bubble_ms", self.bubble_total_ms())
                    .set("stall_mem_ms", self.passes.iter().map(|p| p.stall_mem_ms).sum::<f64>())
                    .set("load_ms", self.passes.iter().map(|p| p.load_ms).sum::<f64>())
                    .set("residual_ms", self.passes.iter().map(|p| p.residual_ms).sum::<f64>()),
            )
            .set("bubble_by_stage_ms", stage_obj)
            .set(
                "totals",
                Value::obj()
                    .set("compute_ms", self.totals.compute_ms)
                    .set("stall_wait_ms", self.totals.stall_wait_ms)
                    .set("stall_mem_ms", self.totals.stall_mem_ms)
                    .set("load_ms", self.totals.load_ms)
                    .set("prefetch_ms", self.totals.prefetch_ms),
            )
            .set(
                "audit",
                Value::obj()
                    .set("ok", self.audit.ok())
                    .set("samples", self.audit.samples)
                    .set("max_drift_bytes", self.audit.max_drift_bytes)
                    .set("settled_used_max", self.audit.settled_used_max)
                    .set("high_water_max", self.audit.high_water_max),
            )
            .set(
                "prefetch_waste",
                Value::obj()
                    .set("events", self.waste.events)
                    .set("bytes", self.waste.bytes)
                    .set("by_reason", reason_obj),
            )
    }

    /// Human-readable report (`hermes analyze` default output).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "trace analysis: {} event(s), {} request(s) ({} served / {} shed / {} failed), \
             {} pass(es), {} batch(es)\n",
            self.events.len(),
            self.requests.len(),
            self.served(),
            self.shed(),
            self.failed(),
            self.passes.len(),
            self.batches,
        ));
        if !self.queue_wait.is_empty() {
            out.push_str(&format!(
                "  queue wait p50 {}  p95 {}    e2e p50 {}  p95 {}\n",
                human_ms(self.queue_wait.p50()),
                human_ms(self.queue_wait.p95()),
                human_ms(self.total_latency.p50()),
                human_ms(self.total_latency.p95()),
            ));
        }
        if !self.passes.is_empty() {
            let compute: f64 = self.passes.iter().map(|p| p.compute_ms).sum();
            let residual: f64 = self.passes.iter().map(|p| p.residual_ms).sum();
            let stages: Vec<String> = self
                .bubble_by_stage
                .iter()
                .map(|(s, b)| format!("s{s} {}", human_ms(*b)))
                .collect();
            out.push_str(&format!(
                "  critical path: compute {}  bubble {}  residual {}\n",
                human_ms(compute),
                human_ms(self.bubble_total_ms()),
                human_ms(residual),
            ));
            if !stages.is_empty() {
                out.push_str(&format!("  bubble by stage: {}\n", stages.join(", ")));
            }
            if let Some(idle) = self.inference_idle_fraction() {
                out.push_str(&format!("  inference idle fraction: {:.0}%\n", idle * 100.0));
            }
        }
        out.push_str(&format!(
            "  stalls: mem {}  wait {}    load {}  prefetch {}\n",
            human_ms(self.totals.stall_mem_ms),
            human_ms(self.totals.stall_wait_ms),
            human_ms(self.totals.load_ms),
            human_ms(self.totals.prefetch_ms),
        ));
        if self.audit.samples > 0 {
            out.push_str(&format!(
                "  memory audit: {} sample(s), max drift {} B ({})  settled max {} / high water {}\n",
                self.audit.samples,
                self.audit.max_drift_bytes,
                if self.audit.ok() { "OK" } else { "DRIFT" },
                human_bytes(self.audit.settled_used_max),
                human_bytes(self.audit.high_water_max),
            ));
        }
        if self.waste.events > 0 {
            let reasons: Vec<String> = self
                .waste
                .by_reason
                .iter()
                .map(|(r, (n, b))| format!("{r}: {n} ({})", human_bytes(*b)))
                .collect();
            out.push_str(&format!(
                "  prefetch waste: {} event(s), {}  [{}]\n",
                self.waste.events,
                human_bytes(self.waste.bytes),
                reasons.join(", "),
            ));
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        if !self.errors.is_empty() {
            out.push_str("errors:\n");
            for e in &self.errors {
                out.push_str(&format!("  - {e}\n"));
            }
        }
        out
    }

    /// Render the reconstructed worker rows as an ASCII Gantt chart —
    /// the figure-1b view, rebuilt from the trace instead of the live
    /// tracer so `hermes analyze` and `hermes report --figure 1b` share
    /// one code path.
    pub fn ascii_gantt(&self, width: usize) -> String {
        let width = width.max(10);
        let spans: Vec<&AnEvent> =
            self.events.iter().filter(|e| e.phase == Phase::Complete && e.dur_us > 0).collect();
        if spans.is_empty() {
            return "(no spans to draw)\n".to_string();
        }
        let t0 = spans.iter().map(|e| e.ts_us).min().unwrap();
        let t1 = spans.iter().map(|e| e.ts_us + e.dur_us).max().unwrap();
        let extent = (t1 - t0).max(1) as f64;
        let mut rows: BTreeMap<(u32, u32), Vec<char>> = BTreeMap::new();
        for ev in &spans {
            let glyph = match ev.name.as_str() {
                "load" => 'L',
                "compute" => '#',
                "prefetch" => 'p',
                "stall_mem" => 's',
                "stall_wait" => '.',
                _ => '+',
            };
            let row = rows.entry((ev.lane, ev.worker)).or_insert_with(|| vec![' '; width]);
            let a = ((ev.ts_us - t0) as f64 / extent * width as f64) as usize;
            let b = (((ev.ts_us + ev.dur_us - t0) as f64 / extent * width as f64).ceil()
                as usize)
                .min(width);
            for cell in row.iter_mut().take(b.max(a + 1).min(width)).skip(a) {
                if *cell == ' ' {
                    *cell = glyph;
                }
            }
        }
        let mut out = String::new();
        out.push_str(&format!("trace gantt over {}\n", human_ms(extent / 1000.0)));
        for ((lane, w), row) in &rows {
            let label = match *w {
                worker::DRIVER => "driver".to_string(),
                worker::INFER => "infer".to_string(),
                worker::DAEMON => "daemon".to_string(),
                t if (10..90).contains(&t) => format!("loader {}", t - 10),
                t => format!("worker {t}"),
            };
            out.push_str(&format!("L{lane} {label:<9} |{}|\n", row.iter().collect::<String>()));
        }
        out.push_str("L=load #=compute p=prefetch s=mem-stall .=wait-stall\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{chrome, EvArgs, Telemetry};

    fn ev(name: &str, phase: Phase, worker: u32, ts: u64, dur: u64) -> AnEvent {
        AnEvent {
            name: name.to_string(),
            phase,
            lane: 0,
            worker,
            ts_us: ts,
            dur_us: dur,
            pass: None,
            stage: None,
            req: None,
            bytes: None,
            reason: None,
            value: None,
        }
    }

    fn req_ev(name: &str, ts: u64, req: u64) -> AnEvent {
        AnEvent { req: Some(req), ..ev(name, Phase::Instant, worker::DRIVER, ts, 0) }
    }

    #[test]
    fn reconstructs_lifecycle_and_critical_path() {
        let mut evs = vec![
            req_ev("enqueue", 0, 1),
            req_ev("admit", 1_000, 1),
            ev("pass", Phase::Begin, worker::DRIVER, 1_000, 0),
            AnEvent { stage: Some(0), ..ev("load", Phase::Complete, worker::loader(0), 1_100, 2_000) },
            AnEvent { stage: Some(0), ..ev("stall_wait", Phase::Complete, worker::INFER, 1_100, 2_000) },
            AnEvent { stage: Some(0), ..ev("compute", Phase::Complete, worker::INFER, 3_100, 1_000) },
            AnEvent { stage: Some(1), ..ev("stall_wait", Phase::Complete, worker::INFER, 4_100, 500) },
            AnEvent { stage: Some(1), ..ev("compute", Phase::Complete, worker::INFER, 4_600, 1_000) },
            ev("pass", Phase::End, worker::DRIVER, 6_000, 0),
            req_ev("retire", 6_200, 1),
        ];
        evs[2].pass = Some(0);
        let a = Analysis::from_events(evs, 0);
        assert!(a.ok(), "errors: {:?}", a.errors);
        assert_eq!(a.requests.len(), 1);
        let r = &a.requests[0];
        assert_eq!(r.outcome, "served");
        assert!((r.queue_ms - 1.0).abs() < 1e-9);
        assert!((r.total_ms - 6.2).abs() < 1e-9);
        assert_eq!(a.passes.len(), 1);
        let p = &a.passes[0];
        assert!((p.dur_ms - 5.0).abs() < 1e-9);
        assert!((p.compute_ms - 2.0).abs() < 1e-9);
        assert!((p.bubble_ms - 2.5).abs() < 1e-9);
        // per-stage attribution totals the pass bubble exactly
        let stage_sum: f64 = p.bubble_by_stage.values().sum();
        assert!((stage_sum - p.bubble_ms).abs() < 1e-9);
        // the critical-path identity: compute + bubble + residual == dur
        assert!((p.compute_ms + p.bubble_ms + p.residual_ms - p.dur_ms).abs() < 1e-9);
        assert!(p.residual_ms >= 0.0);
        // idle fraction: infer row active 1100..5600, busy 2000us of 4500
        let idle = a.inference_idle_fraction().unwrap();
        assert!((idle - (1.0 - 2000.0 / 4500.0)).abs() < 1e-6);
    }

    #[test]
    fn truncated_lifecycles_fail_loudly() {
        // admitted but never retired
        let a = Analysis::from_events(vec![req_ev("enqueue", 0, 1), req_ev("admit", 10, 1)], 0);
        assert!(!a.ok());
        assert!(a.errors.iter().any(|e| e.contains("never retired")), "{:?}", a.errors);
        // enqueued, then nothing
        let a = Analysis::from_events(vec![req_ev("enqueue", 0, 2)], 0);
        assert!(a.errors.iter().any(|e| e.contains("neither admitted nor shed")));
        // dropped events poison the whole reconstruction
        let a = Analysis::from_events(Vec::new(), 3);
        assert!(!a.ok());
        assert!(a.errors[0].contains("incomplete"));
        // unclosed pass span
        let a = Analysis::from_events(vec![ev("pass", Phase::Begin, worker::DRIVER, 0, 0)], 0);
        assert!(a.errors.iter().any(|e| e.contains("never closed")));
        // end without begin
        let a = Analysis::from_events(vec![ev("pass", Phase::End, worker::DRIVER, 5, 0)], 0);
        assert!(a.errors.iter().any(|e| e.contains("never began")));
    }

    #[test]
    fn shed_and_failed_outcomes_classified() {
        let mut shed = req_ev("shed", 500, 7);
        shed.reason = Some("shed_overload".into());
        let mut fail_retire = req_ev("retire", 900, 8);
        fail_retire.reason = Some("internal".into());
        let a = Analysis::from_events(
            vec![req_ev("enqueue", 0, 7), shed, req_ev("enqueue", 100, 8), req_ev("admit", 200, 8), fail_retire],
            0,
        );
        assert!(a.ok(), "{:?}", a.errors);
        assert_eq!(a.shed(), 1);
        assert_eq!(a.failed(), 1);
        assert_eq!(a.served(), 0);
        let s = a.requests.iter().find(|r| r.id == 7).unwrap();
        assert_eq!(s.reason.as_deref(), Some("shed_overload"));
        // shed + admit on one id is contradictory
        let a = Analysis::from_events(
            vec![req_ev("enqueue", 0, 9), req_ev("admit", 1, 9), req_ev("shed", 2, 9)],
            0,
        );
        assert!(a.errors.iter().any(|e| e.contains("both admitted and shed")));
    }

    #[test]
    fn audit_drift_is_an_error_and_zero_drift_is_ok() {
        let mut good = ev("mem_audit", Phase::Counter, worker::DRIVER, 10, 0);
        good.value = Some(4096.0);
        good.bytes = Some(4096);
        let a = Analysis::from_events(vec![good.clone()], 0);
        assert!(a.ok(), "{:?}", a.errors);
        assert_eq!(a.audit.samples, 1);
        assert_eq!(a.audit.max_drift_bytes, 0);

        let mut bad = good.clone();
        bad.bytes = Some(4000);
        let a = Analysis::from_events(vec![good, bad], 0);
        assert!(!a.ok());
        assert_eq!(a.audit.max_drift_bytes, 96);
        assert!(a.errors.iter().any(|e| e.contains("memory audit drift")));

        // settled used above the recorded high-water peak is impossible
        let mut s = ev("mem_audit", Phase::Counter, worker::DRIVER, 10, 0);
        s.value = Some(9000.0);
        s.bytes = Some(9000);
        let mut hw = ev("mem_high_water", Phase::Counter, worker::DRIVER, 20, 0);
        hw.value = Some(8000.0);
        let a = Analysis::from_events(vec![s, hw], 0);
        assert!(a.errors.iter().any(|e| e.contains("high-water")), "{:?}", a.errors);
    }

    #[test]
    fn chrome_round_trip_matches_bus_analysis() {
        let t = Telemetry::on();
        t.instant("enqueue", worker::DRIVER, EvArgs::req(1));
        t.instant("admit", worker::DRIVER, EvArgs::req(1));
        t.begin("pass", worker::DRIVER, EvArgs::pass(0));
        let s = t.now_us();
        t.span("load", worker::loader(0), s, EvArgs::stage(0).with_bytes(4096));
        t.span("compute", worker::INFER, s, EvArgs::stage(0));
        t.end("pass", worker::DRIVER);
        t.counter("mem_audit", worker::DRIVER, 512.0, EvArgs::default().with_bytes(512));
        t.instant("prefetch_waste", worker::DAEMON, EvArgs::default().with_bytes(100).with_reason("evicted"));
        t.instant("retire", worker::DRIVER, EvArgs::req(1));
        let events = t.drain();
        let direct = Analysis::from_bus(&events, 0);
        let doc = chrome::chrome_trace(&events, 0);
        let parsed = Value::parse(&doc.compact()).unwrap();
        let round = Analysis::from_chrome(&parsed).unwrap();
        assert!(direct.ok(), "{:?}", direct.errors);
        assert!(round.ok(), "{:?}", round.errors);
        assert_eq!(direct.requests.len(), round.requests.len());
        assert_eq!(direct.passes.len(), round.passes.len());
        assert_eq!(direct.audit.samples, round.audit.samples);
        assert_eq!(direct.waste.bytes, round.waste.bytes);
        assert_eq!(round.waste.by_reason.get("evicted").map(|(n, b)| (*n, *b)), Some((1, 100)));
        assert!((direct.totals.load_ms - round.totals.load_ms).abs() < 1e-9);
        // json + text render without panicking and agree on ok
        assert!(round.to_json().get("ok").unwrap().as_bool().unwrap());
        assert!(round.render_text().contains("trace analysis"));
    }

    #[test]
    fn gantt_renders_worker_rows() {
        let evs = vec![
            AnEvent { stage: Some(0), ..ev("load", Phase::Complete, worker::loader(1), 0, 500) },
            AnEvent { stage: Some(0), ..ev("compute", Phase::Complete, worker::INFER, 500, 500) },
        ];
        let a = Analysis::from_events(evs, 0);
        let g = a.ascii_gantt(40);
        assert!(g.contains("loader 1"), "{g}");
        assert!(g.contains("infer"), "{g}");
        assert!(g.contains('L') && g.contains('#'), "{g}");
    }
}
