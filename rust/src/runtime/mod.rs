//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute layers.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO **text** is the interchange
//! format (`HloModuleProto::from_text_file` reassigns instruction ids, which
//! sidesteps the 64-bit-id protos jax >= 0.5 emits that xla_extension 0.5.1
//! rejects).  One `PjRtLoadedExecutable` per (profile, layer kind, batch),
//! compiled lazily and cached for the life of the process.
//!
//! THREADING: the `xla` crate's client/executable/literal types wrap
//! `Rc`/raw pointers and are **not Send**.  The Runtime therefore lives on
//! the inference thread only; Loading Agents ship plain `weights::Shard`
//! byte buffers over channels and weight literals are built here, on the
//! compute thread, right before execution.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::model::{DType, EntrySpec, Manifest, Profile, TensorSpec};
use crate::weights::{Shard, Tensor};

/// PJRT CPU client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    prepare_calls: std::cell::Cell<u64>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            executables: RefCell::new(HashMap::new()),
            prepare_calls: std::cell::Cell::new(0),
        })
    }

    pub fn profile(&self, name: &str) -> Result<&Profile> {
        self.manifest.profile(name)
    }

    /// Compile (or fetch cached) the executable for one HLO entry.
    pub fn executable(
        &self,
        profile: &Profile,
        entry: &EntrySpec,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = format!("{}/{}", profile.name, entry.key);
        if let Some(e) = self.executables.borrow().get(&key) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(entry);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", entry.key))?;
        let exe = Rc::new(exe);
        self.executables.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Pre-compile every entry a profile needs (engine warmup; keeps
    /// compilation off the measured path, like the paper's pre-run).
    pub fn prepare(&self, profile: &Profile) -> Result<usize> {
        self.prepare_calls.set(self.prepare_calls.get() + 1);
        let mut n = 0;
        for entry in profile.entries.values() {
            self.executable(profile, entry)?;
            n += 1;
        }
        Ok(n)
    }

    /// How many times [`Runtime::prepare`] ran (tests assert sessions
    /// amortize AOT preparation to exactly once per session).
    pub fn prepare_calls(&self) -> u64 {
        self.prepare_calls.get()
    }

    /// Execute one layer: activation buffers first, then the stage's
    /// weights (uploaded here, owned here, freed on return) in manifest
    /// order.  Returns the single output buffer, which feeds the next
    /// layer's call directly — activations never round-trip through host
    /// literals on the hot path.
    ///
    /// NOTE: this deliberately uses `execute_b` with self-owned input
    /// buffers.  The `xla` crate's literal-based `execute` *leaks every
    /// input buffer* (xla_rs.cc `buffer.release()` without a deleter),
    /// which with per-layer weight inputs leaks the whole model per pass —
    /// see EXPERIMENTS.md §Perf.
    pub fn execute_entry(
        &self,
        profile: &Profile,
        entry: &EntrySpec,
        activations: &[&xla::PjRtBuffer],
        shard: &Shard,
    ) -> Result<xla::PjRtBuffer> {
        let weight_bufs = self.upload_shard(shard)?;
        self.execute_entry_with(profile, entry, activations, &weight_bufs)
    }

    /// Upload every tensor of a stage shard to device buffers, in manifest
    /// order.  Callers that keep the returned buffers alive (the
    /// device-resident layer cache) can re-execute the stage on later
    /// passes without paying this upload again.
    pub fn upload_shard(&self, shard: &Shard) -> Result<Vec<xla::PjRtBuffer>> {
        shard.tensors.iter().map(|t| self.buffer_from_tensor(t)).collect()
    }

    /// [`Runtime::execute_entry`] with the stage's weights already on the
    /// device — the hot path for device-cache hits, and the shared tail of
    /// every execute (one upload can serve several entries of one stage,
    /// e.g. a KV prime entry plus the main entry).
    pub fn execute_entry_with(
        &self,
        profile: &Profile,
        entry: &EntrySpec,
        activations: &[&xla::PjRtBuffer],
        weights: &[xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        let exe = self.executable(profile, entry)?;
        if activations.len() != entry.activations.len() {
            bail!(
                "{}: expected {} activation(s), got {}",
                entry.key,
                entry.activations.len(),
                activations.len()
            );
        }
        let mut inputs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(activations.len() + weights.len());
        inputs.extend_from_slice(activations);
        inputs.extend(weights.iter());
        let mut out = exe.execute_b::<&xla::PjRtBuffer>(&inputs)?;
        // return_tuple=False in aot.py: exactly one output array buffer.
        if out.is_empty() || out[0].is_empty() {
            bail!("{}: executable produced no outputs", entry.key);
        }
        Ok(out[0].swap_remove(0))
    }

    /// Upload a shard tensor to a device buffer.
    ///
    /// Uses the typed `buffer_from_host_buffer`, the only upload wrapper in
    /// the crate that is BOTH type-correct (it passes `PrimitiveType` over
    /// the C ABI; `buffer_from_host_raw_bytes` passes `ElementType`
    /// discriminants, turning F32 into F16) AND synchronous
    /// (`kImmutableOnlyDuringCall` copies before returning;
    /// `buffer_from_host_literal` transfers async and segfaults if the
    /// literal is dropped before the copy lands).  The typed slice costs
    /// one aligned host copy per tensor.
    pub fn buffer_from_tensor(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        match t.dtype {
            DType::F32 => {
                Ok(self.client.buffer_from_host_buffer(&t.as_f32()?, &t.shape, None)?)
            }
            DType::I32 => {
                Ok(self.client.buffer_from_host_buffer(&t.as_i32()?, &t.shape, None)?)
            }
            other => bail!("unsupported upload dtype {other:?}"),
        }
    }

    /// Upload typed host values to a device buffer.
    pub fn buffer_f32(&self, values: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(values, shape, None)?)
    }

    pub fn buffer_i32(&self, values: &[i32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(values, shape, None)?)
    }

    /// Pull a device buffer back to host f32s (final outputs only).
    pub fn buffer_to_f32(&self, b: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        Ok(b.to_literal_sync()?.to_vec::<f32>()?)
    }
}

// ---------------------------------------------------------------------------
// literal construction / extraction helpers
// ---------------------------------------------------------------------------

/// Build an XLA literal from a shard tensor's raw little-endian bytes.
pub fn literal_from_tensor(t: &Tensor) -> Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(t.dtype.xla(), &t.shape, &t.data)?)
}

/// f32 literal from values + shape.
pub fn literal_f32(shape: &[usize], values: &[f32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != values.len() {
        bail!("shape {:?} needs {} values, got {}", shape, n, values.len());
    }
    let bytes: Vec<u8> = values.iter().flat_map(|v| v.to_le_bytes()).collect();
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, &bytes)?)
}

/// i32 literal from values + shape.
pub fn literal_i32(shape: &[usize], values: &[i32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != values.len() {
        bail!("shape {:?} needs {} values, got {}", shape, n, values.len());
    }
    let bytes: Vec<u8> = values.iter().flat_map(|v| v.to_le_bytes()).collect();
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, &bytes)?)
}

/// Pull an f32 literal back into a Vec.
pub fn literal_to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Build the input literal described by an activation spec from raw values.
pub fn literal_for_spec(spec: &TensorSpec, f32s: Option<&[f32]>, i32s: Option<&[i32]>) -> Result<xla::Literal> {
    match spec.dtype {
        DType::F32 => literal_f32(&spec.shape, f32s.context("f32 input required")?),
        DType::I32 => literal_i32(&spec.shape, i32s.context("i32 input required")?),
        other => bail!("unsupported input dtype {other:?}"),
    }
}

/// Total bytes of an activation spec (for the memory accountant).
pub fn spec_bytes(spec: &TensorSpec) -> u64 {
    spec.num_bytes() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_f32_roundtrip() {
        let l = literal_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(l.element_count(), 6);
        assert_eq!(literal_to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[2, 2], &[1.0]).is_err());
        assert!(literal_i32(&[3], &[1, 2]).is_err());
    }

    #[test]
    fn literal_from_tensor_preserves_bytes() {
        let t = Tensor {
            name: "w".into(),
            dtype: DType::F32,
            shape: vec![4],
            data: [1f32, -2.0, 3.5, 0.0].iter().flat_map(|v| v.to_le_bytes()).collect(),
        };
        let l = literal_from_tensor(&t).unwrap();
        assert_eq!(literal_to_f32(&l).unwrap(), vec![1.0, -2.0, 3.5, 0.0]);
    }
}
