//! Layer Profiler (paper section IV-1).
//!
//! Pre-runs a standard model inference, measuring for every individual
//! layer: **loading time** (through the edge-storage simulator), **compute
//! time** (PJRT execution), and **memory size** (shard weight bytes).
//! The Pipeline Planner consumes this profile to size the Loading-Agent
//! pool per memory constraint; `hermes report --figure 3` renders the
//! load-vs-compute decomposition (Obs II).

use std::path::Path;

use anyhow::{Context, Result};

use crate::diskio::Disk;
use crate::model::Profile;
use crate::pipeload::{ExecCtx, ModelInput};
use crate::runtime::Runtime;
use crate::util::json::Value;
use crate::weights::read_shard_from;

/// One layer's measurements.
#[derive(Debug, Clone)]
pub struct LayerProfile {
    pub stage: usize,
    pub kind: String,
    pub load_ms: f64,
    pub compute_ms: f64,
    pub bytes: u64,
}

/// The whole model's profile.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub profile: String,
    pub disk: String,
    pub batch: usize,
    pub layers: Vec<LayerProfile>,
}

impl ModelProfile {
    /// Mean load/compute over the body (encoder/decoder) layers only —
    /// the layers PIPELOAD schedules around (Obs I).
    pub fn body_means(&self, body_kind: &str) -> (f64, f64, u64) {
        let body: Vec<&LayerProfile> =
            self.layers.iter().filter(|l| l.kind == body_kind).collect();
        if body.is_empty() {
            return (0.0, 0.0, 0);
        }
        let n = body.len() as f64;
        (
            body.iter().map(|l| l.load_ms).sum::<f64>() / n,
            body.iter().map(|l| l.compute_ms).sum::<f64>() / n,
            (body.iter().map(|l| l.bytes).sum::<u64>() as f64 / n) as u64,
        )
    }

    /// Load/compute ratio over body layers (paper Fig 3: ~10x for ~1 GB
    /// models, ~2x for GPT-J).
    pub fn load_compute_ratio(&self, body_kind: &str) -> f64 {
        let (l, c, _) = self.body_means(body_kind);
        if c > 0.0 {
            l / c
        } else {
            f64::INFINITY
        }
    }

    pub fn total_load_ms(&self) -> f64 {
        self.layers.iter().map(|l| l.load_ms).sum()
    }

    pub fn total_compute_ms(&self) -> f64 {
        self.layers.iter().map(|l| l.compute_ms).sum()
    }

    pub fn max_stage_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.bytes).max().unwrap_or(0)
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("profile", self.profile.clone())
            .set("disk", self.disk.clone())
            .set("batch", self.batch)
            .set(
                "layers",
                Value::Arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            Value::obj()
                                .set("stage", l.stage)
                                .set("kind", l.kind.clone())
                                .set("load_ms", l.load_ms)
                                .set("compute_ms", l.compute_ms)
                                .set("bytes", l.bytes)
                        })
                        .collect(),
                ),
            )
    }

    pub fn from_json(v: &Value) -> Result<ModelProfile> {
        Ok(ModelProfile {
            profile: v.req("profile")?.as_str()?.to_string(),
            disk: v.req("disk")?.as_str()?.to_string(),
            batch: v.req("batch")?.as_usize()?,
            layers: v
                .req("layers")?
                .as_arr()?
                .iter()
                .map(|l| {
                    Ok(LayerProfile {
                        stage: l.req("stage")?.as_usize()?,
                        kind: l.req("kind")?.as_str()?.to_string(),
                        load_ms: l.req("load_ms")?.as_f64()?,
                        compute_ms: l.req("compute_ms")?.as_f64()?,
                        bytes: l.req("bytes")?.as_f64()? as u64,
                    })
                })
                .collect::<Result<_>>()?,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_json().to_file(path)
    }

    pub fn load(path: &Path) -> Result<ModelProfile> {
        ModelProfile::from_json(&Value::from_file(path)?)
            .with_context(|| format!("parsing profile {}", path.display()))
    }
}

/// Pre-run: load + execute every stage once, measuring each phase.
pub fn profile_model(
    runtime: &Runtime,
    profile: &Profile,
    weights_dir: &Path,
    disk: &Disk,
    batch: usize,
    input: &ModelInput,
) -> Result<ModelProfile> {
    let ctx = ExecCtx {
        runtime,
        profile,
        shard_dir: weights_dir.join(&profile.name),
        disk: disk.clone(),
        tracer: crate::trace::Tracer::disabled(),
        telemetry: crate::telemetry::Telemetry::off(),
        signals: crate::signals::SignalLog::new(),
        batch,
    };
    runtime.prepare(profile)?;
    let mut layers = Vec::with_capacity(profile.stages.len());
    let mut act: Option<xla::PjRtBuffer> = None;
    let mut enc_out: Option<xla::PjRtBuffer> = None;

    for (k, stage) in profile.stages.iter().enumerate() {
        let t0 = std::time::Instant::now();
        let reader = ctx.disk.open(&ctx.shard_dir.join(&stage.shard))?;
        let shard = read_shard_from(reader)
            .with_context(|| format!("profiling shard {}", stage.shard))?;
        let load_ms = t0.elapsed().as_secs_f64() * 1000.0;

        let entry = profile.entry(&stage.kind, batch)?;
        if k == 0 {
            act = Some(input.to_buffer(runtime, &entry.activations[0])?);
        } else if stage.kind == "cross_decoder_layer" && enc_out.is_none() {
            enc_out = act.take();
        }
        let x_ref;
        let act_refs: Vec<&xla::PjRtBuffer> = if stage.kind == "cross_decoder_layer" {
            let enc = enc_out.as_ref().unwrap();
            match act.as_ref() {
                Some(x) => vec![x, enc],
                None => vec![enc, enc],
            }
        } else {
            x_ref = act.as_ref().unwrap();
            vec![x_ref]
        };
        let t1 = std::time::Instant::now();
        let out = runtime.execute_entry(profile, entry, &act_refs, &shard)?;
        let compute_ms = t1.elapsed().as_secs_f64() * 1000.0;
        act = Some(out);

        layers.push(LayerProfile {
            stage: k,
            kind: stage.kind.clone(),
            load_ms,
            compute_ms,
            bytes: profile.stage_bytes(stage),
        });
    }
    Ok(ModelProfile {
        profile: profile.name.clone(),
        disk: disk.profile.name.clone(),
        batch,
        layers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ModelProfile {
        ModelProfile {
            profile: "p".into(),
            disk: "edge-emmc".into(),
            batch: 1,
            layers: vec![
                LayerProfile { stage: 0, kind: "embedding".into(), load_ms: 5.0, compute_ms: 1.0, bytes: 100 },
                LayerProfile { stage: 1, kind: "encoder_layer".into(), load_ms: 20.0, compute_ms: 2.0, bytes: 400 },
                LayerProfile { stage: 2, kind: "encoder_layer".into(), load_ms: 24.0, compute_ms: 2.0, bytes: 400 },
                LayerProfile { stage: 3, kind: "pooler".into(), load_ms: 1.0, compute_ms: 0.5, bytes: 50 },
            ],
        }
    }

    #[test]
    fn body_means_filter_body_layers_only() {
        let p = sample();
        let (l, c, b) = p.body_means("encoder_layer");
        assert!((l - 22.0).abs() < 1e-9);
        assert!((c - 2.0).abs() < 1e-9);
        assert_eq!(b, 400);
        assert!((p.load_compute_ratio("encoder_layer") - 11.0).abs() < 1e-9);
    }

    #[test]
    fn totals_and_max() {
        let p = sample();
        assert!((p.total_load_ms() - 50.0).abs() < 1e-9);
        assert!((p.total_compute_ms() - 5.5).abs() < 1e-9);
        assert_eq!(p.max_stage_bytes(), 400);
    }

    #[test]
    fn json_roundtrip() {
        let p = sample();
        let v = p.to_json();
        let q = ModelProfile::from_json(&v).unwrap();
        assert_eq!(q.layers.len(), 4);
        assert_eq!(q.layers[1].bytes, 400);
        assert_eq!(q.profile, "p");
    }

    #[test]
    fn empty_body_kind_safe() {
        let p = sample();
        let (l, c, b) = p.body_means("gptj_layer");
        assert_eq!((l, c, b), (0.0, 0.0, 0));
        assert!(p.load_compute_ratio("gptj_layer").is_infinite());
    }
}
