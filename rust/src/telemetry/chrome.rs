//! Chrome trace-event JSON backend for the telemetry bus.
//!
//! Serializes drained [`Event`]s into the trace-event format that
//! Perfetto (<https://ui.perfetto.dev>) and `chrome://tracing` load
//! directly: `pid` = serving lane, `tid` = worker slot (see
//! [`super::worker`]), timestamps in microseconds.  Span phases map
//! 1:1 — [`Phase::Complete`] → `X`, [`Phase::Begin`]/[`Phase::End`] →
//! `B`/`E`, [`Phase::Instant`] → `i`, [`Phase::Counter`] → `C` — plus
//! synthesized `M` metadata events naming each lane row and worker row.
//!
//! [`validate`] is the schema check the tests (and `make trace-smoke`)
//! run against an emitted file: balanced `B`/`E` stacks per (pid, tid),
//! monotonic `B`/`E` timestamps per thread row, and required keys on
//! every event.

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Event, Phase};
use crate::util::json::Value;

fn worker_name(tid: u32) -> String {
    match tid {
        super::worker::DRIVER => "driver".to_string(),
        super::worker::INFER => "inference".to_string(),
        super::worker::DAEMON => "daemon".to_string(),
        t if (10..90).contains(&t) => format!("loader {}", t - 10),
        t => format!("worker {t}"),
    }
}

fn args_json(ev: &Event) -> Value {
    let mut o = Value::obj();
    if let Some(p) = ev.args.pass {
        o = o.set("pass", p);
    }
    if let Some(e) = ev.args.epoch {
        o = o.set("epoch", e);
    }
    if let Some(s) = ev.args.stage {
        o = o.set("stage", s);
    }
    if let Some(r) = ev.args.req {
        o = o.set("req", r);
    }
    if let Some(b) = ev.args.bytes {
        o = o.set("bytes", b);
    }
    if let Some(r) = ev.args.reason {
        o = o.set("reason", r);
    }
    if let Some(v) = ev.args.value {
        o = o.set("value", v);
    }
    o
}

/// Build the full Chrome trace document from drained events.
pub fn chrome_trace(events: &[Event], dropped: u64) -> Value {
    let mut out: Vec<Value> = Vec::with_capacity(events.len() + 16);

    // metadata rows first: name every (pid) lane and (pid, tid) worker
    let pids: BTreeSet<u32> = events.iter().map(|e| e.lane).collect();
    let rows: BTreeSet<(u32, u32)> = events.iter().map(|e| (e.lane, e.worker)).collect();
    for pid in &pids {
        out.push(
            Value::obj()
                .set("name", "process_name")
                .set("ph", "M")
                .set("pid", *pid as u64)
                .set("tid", 0u64)
                .set("args", Value::obj().set("name", format!("lane {pid}"))),
        );
    }
    for (pid, tid) in &rows {
        out.push(
            Value::obj()
                .set("name", "thread_name")
                .set("ph", "M")
                .set("pid", *pid as u64)
                .set("tid", *tid as u64)
                .set("args", Value::obj().set("name", worker_name(*tid))),
        );
    }

    for ev in events {
        let mut o = Value::obj()
            .set("name", ev.name)
            .set("cat", "hermes")
            .set("ph", match ev.phase {
                Phase::Begin => "B",
                Phase::End => "E",
                Phase::Instant => "i",
                Phase::Complete => "X",
                Phase::Counter => "C",
            })
            .set("ts", ev.ts_us)
            .set("pid", ev.lane as u64)
            .set("tid", ev.worker as u64);
        if ev.phase == Phase::Complete {
            o = o.set("dur", ev.dur_us);
        }
        if ev.phase == Phase::Instant {
            o = o.set("s", "t"); // thread-scoped instant
        }
        o = o.set("args", args_json(ev));
        out.push(o);
    }

    Value::obj()
        .set("traceEvents", Value::Arr(out))
        .set("displayTimeUnit", "ms")
        .set("otherData", Value::obj().set("dropped_events", dropped))
}

/// Serialize and write the trace document to `path`.
pub fn write_chrome_trace(path: &Path, events: &[Event], dropped: u64) -> Result<()> {
    chrome_trace(events, dropped)
        .to_file(path)
        .with_context(|| format!("writing Chrome trace to {}", path.display()))
}

/// Schema validation for an emitted trace document (tests +
/// `trace-smoke`): required keys, balanced `B`/`E` per (pid, tid), and
/// monotonic `B`/`E` timestamps within each thread row.
pub fn validate(doc: &Value) -> Result<()> {
    let events = doc
        .get("traceEvents")
        .context("missing traceEvents")?
        .as_arr()
        .context("traceEvents is not an array")?;
    // (pid, tid) -> open B names; (pid, tid) -> last B/E ts
    let mut stacks: std::collections::BTreeMap<(u64, u64), Vec<String>> = Default::default();
    let mut last_ts: std::collections::BTreeMap<(u64, u64), f64> = Default::default();
    for (i, ev) in events.iter().enumerate() {
        let ph = ev.get("ph").with_context(|| format!("event {i}: missing ph"))?.as_str()?;
        let pid = ev.get("pid").with_context(|| format!("event {i}: missing pid"))?.as_f64()?
            as u64;
        let tid = ev.get("tid").with_context(|| format!("event {i}: missing tid"))?.as_f64()?
            as u64;
        if ph == "M" {
            continue;
        }
        let name = ev.get("name").with_context(|| format!("event {i}: missing name"))?.as_str()?;
        let ts = ev.get("ts").with_context(|| format!("event {i}: missing ts"))?.as_f64()?;
        if ts < 0.0 {
            bail!("event {i} ({name}): negative ts");
        }
        match ph {
            "B" | "E" => {
                let key = (pid, tid);
                if let Some(prev) = last_ts.get(&key) {
                    if ts < *prev {
                        bail!("event {i} ({name}): B/E ts not monotonic on pid {pid} tid {tid}");
                    }
                }
                last_ts.insert(key, ts);
                let stack = stacks.entry(key).or_default();
                if ph == "B" {
                    stack.push(name.to_string());
                } else {
                    let open = stack.pop().with_context(|| {
                        format!("event {i} ({name}): E with no open B on pid {pid} tid {tid}")
                    })?;
                    if open != name {
                        bail!("event {i}: E '{name}' closes B '{open}' on pid {pid} tid {tid}");
                    }
                }
            }
            "X" => {
                let dur = ev
                    .get("dur")
                    .with_context(|| format!("event {i} ({name}): X without dur"))?
                    .as_f64()?;
                if dur < 0.0 {
                    bail!("event {i} ({name}): negative dur");
                }
            }
            "i" | "C" => {}
            other => bail!("event {i} ({name}): unknown phase '{other}'"),
        }
    }
    for ((pid, tid), stack) in &stacks {
        if !stack.is_empty() {
            bail!("unclosed B span(s) {stack:?} on pid {pid} tid {tid}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::{worker, EvArgs, Telemetry};
    use super::*;

    #[test]
    fn trace_document_round_trips_and_validates() {
        let t = Telemetry::on().with_lane(1);
        t.begin("pass", worker::DRIVER, EvArgs::pass(0));
        t.instant("enqueue", worker::DRIVER, EvArgs::req(3));
        let s = t.now_us();
        t.span("load", worker::loader(0), s, EvArgs::stage(2).with_bytes(4096));
        t.counter("mem_high_water", worker::DRIVER, 1e6, EvArgs::pass(0));
        t.end("pass", worker::DRIVER);
        let doc = chrome_trace(&t.drain(), t.dropped());
        // survives serialize -> parse
        let parsed = Value::parse(&doc.compact()).unwrap();
        validate(&parsed).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata rows for pid + per-(pid,tid) names + 5 events
        assert!(evs.iter().any(|e| e.get("ph").unwrap().as_str().unwrap() == "M"));
        let load = evs
            .iter()
            .find(|e| e.get("name").map(|n| n.as_str().unwrap()) == Some("load"))
            .unwrap();
        assert_eq!(load.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(load.get("args").unwrap().get("bytes").unwrap().as_usize().unwrap(), 4096);
        assert_eq!(load.get("tid").unwrap().as_usize().unwrap(), 10);
        assert_eq!(load.get("pid").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn validate_rejects_unbalanced_spans() {
        let t = Telemetry::on();
        t.begin("pass", worker::DRIVER, EvArgs::default());
        let doc = chrome_trace(&t.drain(), 0);
        assert!(validate(&doc).unwrap_err().to_string().contains("unclosed"));

        let t = Telemetry::on();
        t.end("pass", worker::DRIVER);
        let doc = chrome_trace(&t.drain(), 0);
        assert!(validate(&doc).unwrap_err().to_string().contains("no open B"));
    }

    #[test]
    fn validate_rejects_mismatched_nesting() {
        let t = Telemetry::on();
        t.begin("outer", worker::DRIVER, EvArgs::default());
        t.begin("inner", worker::DRIVER, EvArgs::default());
        t.end("outer", worker::DRIVER); // wrong: closes 'inner'
        t.end("inner", worker::DRIVER);
        let doc = chrome_trace(&t.drain(), 0);
        assert!(validate(&doc).is_err());
    }

    #[test]
    fn dropped_count_lands_in_other_data() {
        let t = Telemetry::with_capacity(1);
        t.instant("a", 0, EvArgs::default());
        t.instant("b", 0, EvArgs::default());
        let doc = chrome_trace(&t.drain(), t.dropped());
        assert_eq!(
            doc.get("otherData").unwrap().get("dropped_events").unwrap().as_usize().unwrap(),
            1
        );
    }
}
