//! Unified telemetry: a bounded, lock-cheap structured event bus.
//!
//! Every layer of the stack emits [`Event`]s through a cloned
//! [`Telemetry`] handle — per-request lifecycle instants in the serving
//! layer (enqueue → admit/shed → prime → decode_step → retire), per-stage
//! worker spans in `pipeload` (load / compute / stall-mem / stall-wait /
//! prefetch / device-hit / evict), accountant high-water counters in
//! `memory`, and elastic `BudgetEpoch` + KV dedup/COW instants.  Two
//! consumers read the bus: the Chrome trace-event writer
//! ([`chrome::chrome_trace`], behind `--trace-out`) and the live
//! `{"op":"stats"}` / `{"op":"metrics"}` TCP surface.  In-process
//! consumers attach through [`Telemetry::subscribe`]: each subscriber
//! owns a bounded ring that the emit path appends to without ever
//! blocking — a slow subscriber drops *its own* copies (counted per
//! subscriber), never the shard record and never the emitter.  The
//! `analyze::DerivedSignals` aggregator (rolling-window health rates
//! behind `{"op":"health"}`) is the first such consumer, and the hook
//! a closed-loop elastic controller attaches to.
//!
//! Design constraints (the whole point of this module):
//!
//! * **disabled is near-free** — [`Telemetry::is_on`] is a single
//!   `Relaxed` atomic load; every emit helper checks it first, and hot
//!   call sites guard externally so argument structs are never even
//!   built.  Telemetry must never perturb the tokens it observes: it
//!   only reads timestamps, it never gates execution.
//! * **bounded** — each emitting thread appends to its own shard (an
//!   uncontended mutex in practice; threads never share a shard), capped
//!   at `cap_per_shard` events.  A full shard drops the event and bumps a
//!   global counter exposed as [`Telemetry::dropped`] — backpressure
//!   never reaches the serving path.
//! * **lane-scoped** — handles are cheap to clone; [`Telemetry::with_lane`]
//!   rebinds the lane tag (the Chrome `pid`) so per-lane executors stamp
//!   every event without threading an extra argument around.
//!
//! Worker ids (the Chrome `tid`) follow the [`worker`] convention so
//! traces render with a stable row layout per lane.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Instant;

pub mod chrome;

/// Default per-shard event capacity (events, not bytes).  A two-lane
/// continuous serve with a few hundred tokens emits a few thousand
/// events; 1<<16 leaves generous headroom before drops start.
pub const DEFAULT_SHARD_CAP: usize = 1 << 16;

/// Well-known worker slots (Chrome `tid`) inside one lane's process row.
pub mod worker {
    /// the serving driver / router loop (lifecycle events)
    pub const DRIVER: u32 = 0;
    /// the inference agent — compute runs on the session's calling thread
    pub const INFER: u32 = 1;
    /// the memory daemon (pin / destroy decisions)
    pub const DAEMON: u32 = 90;

    /// loading agent `i` (worker-pool loader threads)
    pub fn loader(i: usize) -> u32 {
        10 + i as u32
    }
}

/// Event phase, mirroring the Chrome trace-event phases we emit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// `B` — begin a nested span on this (lane, worker) row.  Only used
    /// for strictly sequential per-thread spans (pass boundaries).
    Begin,
    /// `E` — end the innermost open span on this row
    End,
    /// `i` — a point-in-time marker (lifecycle edges, evictions, dedup)
    Instant,
    /// `X` — a complete span with an explicit duration (load / compute /
    /// stalls / prefetch), safe under overlap because it carries its own
    /// extent instead of relying on a per-thread stack
    Complete,
    /// `C` — a sampled counter series (accountant high-water bytes)
    Counter,
}

/// Optional structured payload; unset fields stay out of the JSON.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvArgs {
    pub pass: Option<u64>,
    pub epoch: Option<u64>,
    pub stage: Option<usize>,
    /// request id (serving lifecycle events)
    pub req: Option<u64>,
    pub bytes: Option<u64>,
    /// static cause tag (shed reason, eviction cause, …)
    pub reason: Option<&'static str>,
    /// counter sample value
    pub value: Option<f64>,
}

impl EvArgs {
    pub fn pass(pass: u64) -> EvArgs {
        EvArgs { pass: Some(pass), ..EvArgs::default() }
    }

    pub fn stage(stage: usize) -> EvArgs {
        EvArgs { stage: Some(stage), ..EvArgs::default() }
    }

    pub fn req(req: u64) -> EvArgs {
        EvArgs { req: Some(req), ..EvArgs::default() }
    }

    pub fn with_pass(mut self, pass: u64) -> EvArgs {
        self.pass = Some(pass);
        self
    }

    pub fn with_epoch(mut self, epoch: u64) -> EvArgs {
        self.epoch = Some(epoch);
        self
    }

    pub fn with_stage(mut self, stage: usize) -> EvArgs {
        self.stage = Some(stage);
        self
    }

    pub fn with_req(mut self, req: u64) -> EvArgs {
        self.req = Some(req);
        self
    }

    pub fn with_bytes(mut self, bytes: u64) -> EvArgs {
        self.bytes = Some(bytes);
        self
    }

    pub fn with_reason(mut self, reason: &'static str) -> EvArgs {
        self.reason = Some(reason);
        self
    }
}

/// One structured telemetry event.
#[derive(Debug, Clone)]
pub struct Event {
    pub name: &'static str,
    pub phase: Phase,
    /// Chrome `pid`: the serving lane (0 for single-session runs)
    pub lane: u32,
    /// Chrome `tid`: see [`worker`]
    pub worker: u32,
    /// microseconds since the bus was created
    pub ts_us: u64,
    /// span extent for [`Phase::Complete`]; 0 otherwise
    pub dur_us: u64,
    pub args: EvArgs,
}

struct Shard {
    events: Mutex<Vec<Event>>,
}

struct SubInner {
    label: String,
    cap: usize,
    buf: Mutex<VecDeque<Event>>,
    dropped: AtomicU64,
}

/// Handle on one bounded subscriber ring (see [`Telemetry::subscribe`]).
/// Dropping the handle detaches the subscriber from the bus.
pub struct Subscription {
    sub: Arc<SubInner>,
}

impl Subscription {
    /// Drain every buffered event in emission order.
    pub fn drain(&self) -> Vec<Event> {
        self.sub.buf.lock().unwrap().drain(..).collect()
    }

    /// Events this subscriber missed because its ring was full.
    pub fn dropped(&self) -> u64 {
        self.sub.dropped.load(Ordering::Relaxed)
    }

    pub fn label(&self) -> &str {
        &self.sub.label
    }
}

struct Inner {
    /// unique bus id — the thread-local registry key (pointer identity
    /// would be unsound across bus drop/realloc)
    id: u64,
    enabled: AtomicBool,
    start: Instant,
    shards: Mutex<Vec<Arc<Shard>>>,
    dropped: AtomicU64,
    cap_per_shard: usize,
    /// weak refs so a dropped [`Subscription`] self-detaches; pruned on
    /// the next fan-out
    subs: Mutex<Vec<Weak<SubInner>>>,
    /// fast-path gate: emitters skip the subscriber lock entirely while
    /// nothing is attached
    sub_count: AtomicUsize,
}

static NEXT_BUS_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// per-thread shard cache, keyed by bus id; a thread touches few
    /// buses, so a linear scan beats a map
    static LOCAL_SHARDS: RefCell<Vec<(u64, Arc<Shard>)>> = const { RefCell::new(Vec::new()) };
}

/// Cloneable handle on the event bus.  `Clone` is an `Arc` bump; the
/// `lane` tag rides on the handle so per-lane clones stamp it for free.
#[derive(Clone)]
pub struct Telemetry {
    inner: Arc<Inner>,
    lane: u32,
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Telemetry")
            .field("on", &self.is_on())
            .field("lane", &self.lane)
            .finish()
    }
}

impl Default for Telemetry {
    fn default() -> Telemetry {
        Telemetry::off()
    }
}

impl Telemetry {
    fn build(enabled: bool, cap_per_shard: usize) -> Telemetry {
        Telemetry {
            inner: Arc::new(Inner {
                id: NEXT_BUS_ID.fetch_add(1, Ordering::Relaxed),
                enabled: AtomicBool::new(enabled),
                start: Instant::now(),
                shards: Mutex::new(Vec::new()),
                dropped: AtomicU64::new(0),
                cap_per_shard,
                subs: Mutex::new(Vec::new()),
                sub_count: AtomicUsize::new(0),
            }),
            lane: 0,
        }
    }

    /// A disabled bus: every emit is one atomic load and a branch.
    pub fn off() -> Telemetry {
        Telemetry::build(false, DEFAULT_SHARD_CAP)
    }

    /// An enabled bus with the default per-shard capacity.
    pub fn on() -> Telemetry {
        Telemetry::build(true, DEFAULT_SHARD_CAP)
    }

    /// An enabled bus with an explicit per-shard capacity (tests exercise
    /// the drop path with tiny caps).
    pub fn with_capacity(cap_per_shard: usize) -> Telemetry {
        Telemetry::build(true, cap_per_shard.max(1))
    }

    /// THE disabled-path check: a single `Relaxed` atomic load.
    #[inline]
    pub fn is_on(&self) -> bool {
        self.inner.enabled.load(Ordering::Relaxed)
    }

    /// Rebind the lane tag (Chrome `pid`) on a cheap clone.
    pub fn with_lane(&self, lane: u32) -> Telemetry {
        Telemetry { inner: Arc::clone(&self.inner), lane }
    }

    pub fn lane(&self) -> u32 {
        self.lane
    }

    /// Microseconds since the bus was created (span timing).
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.inner.start.elapsed().as_micros() as u64
    }

    /// Events dropped because a shard was full.
    pub fn dropped(&self) -> u64 {
        self.inner.dropped.load(Ordering::Relaxed)
    }

    /// Attach a bounded, non-blocking subscriber ring.  Every event that
    /// reaches [`push`](Self::push) is also copied into the ring; when it
    /// is full the *copy* is dropped and counted on the subscriber — the
    /// shard record and the emitting thread are never affected.  Dropping
    /// the returned [`Subscription`] detaches it.
    pub fn subscribe(&self, label: impl Into<String>, cap: usize) -> Subscription {
        let sub = Arc::new(SubInner {
            label: label.into(),
            cap: cap.max(1),
            buf: Mutex::new(VecDeque::new()),
            dropped: AtomicU64::new(0),
        });
        let mut subs = self.inner.subs.lock().unwrap();
        subs.push(Arc::downgrade(&sub));
        self.inner.sub_count.store(subs.len(), Ordering::Release);
        Subscription { sub }
    }

    /// Per-subscriber drop counts for the live stats surfaces.
    pub fn subscriber_drops(&self) -> Vec<(String, u64)> {
        self.inner
            .subs
            .lock()
            .unwrap()
            .iter()
            .filter_map(|w| w.upgrade())
            .map(|s| (s.label.clone(), s.dropped.load(Ordering::Relaxed)))
            .collect()
    }

    fn fan_out(&self, ev: &Event) {
        let mut subs = self.inner.subs.lock().unwrap();
        let before = subs.len();
        subs.retain(|w| match w.upgrade() {
            Some(s) => {
                let mut buf = s.buf.lock().unwrap();
                if buf.len() < s.cap {
                    buf.push_back(ev.clone());
                } else {
                    s.dropped.fetch_add(1, Ordering::Relaxed);
                }
                true
            }
            None => false,
        });
        if subs.len() != before {
            self.inner.sub_count.store(subs.len(), Ordering::Release);
        }
    }

    fn push(&self, ev: Event) {
        let inner = &self.inner;
        if inner.sub_count.load(Ordering::Acquire) > 0 {
            self.fan_out(&ev);
        }
        LOCAL_SHARDS.with(|reg| {
            let mut reg = reg.borrow_mut();
            let shard = match reg.iter().find(|(id, _)| *id == inner.id) {
                Some((_, s)) => Arc::clone(s),
                None => {
                    let s = Arc::new(Shard { events: Mutex::new(Vec::new()) });
                    inner.shards.lock().unwrap().push(Arc::clone(&s));
                    reg.push((inner.id, Arc::clone(&s)));
                    s
                }
            };
            let mut events = shard.events.lock().unwrap();
            if events.len() < inner.cap_per_shard {
                events.push(ev);
            } else {
                inner.dropped.fetch_add(1, Ordering::Relaxed);
            }
        });
    }

    /// Point event (lifecycle edges, evictions, dedup/COW, shed).
    #[inline]
    pub fn instant(&self, name: &'static str, worker: u32, args: EvArgs) {
        if !self.is_on() {
            return;
        }
        let ts_us = self.now_us();
        self.push(Event {
            name,
            phase: Phase::Instant,
            lane: self.lane,
            worker,
            ts_us,
            dur_us: 0,
            args,
        });
    }

    /// Complete span from a caller-sampled start (`now_us()` at entry).
    /// Safe under overlap: the event carries its own extent.
    #[inline]
    pub fn span(&self, name: &'static str, worker: u32, start_us: u64, args: EvArgs) {
        if !self.is_on() {
            return;
        }
        let now = self.now_us();
        self.push(Event {
            name,
            phase: Phase::Complete,
            lane: self.lane,
            worker,
            ts_us: start_us,
            dur_us: now.saturating_sub(start_us),
            args,
        });
    }

    /// Begin a nested span.  ONLY for strictly sequential spans on one
    /// (lane, worker) row — Chrome pairs `B`/`E` on a per-thread stack.
    #[inline]
    pub fn begin(&self, name: &'static str, worker: u32, args: EvArgs) {
        if !self.is_on() {
            return;
        }
        let ts_us = self.now_us();
        self.push(Event {
            name,
            phase: Phase::Begin,
            lane: self.lane,
            worker,
            ts_us,
            dur_us: 0,
            args,
        });
    }

    /// End the innermost open span on this (lane, worker) row.
    #[inline]
    pub fn end(&self, name: &'static str, worker: u32) {
        if !self.is_on() {
            return;
        }
        let ts_us = self.now_us();
        self.push(Event {
            name,
            phase: Phase::End,
            lane: self.lane,
            worker,
            ts_us,
            dur_us: 0,
            args: EvArgs::default(),
        });
    }

    /// Counter sample (accountant high-water bytes per pass).
    #[inline]
    pub fn counter(&self, name: &'static str, worker: u32, value: f64, args: EvArgs) {
        if !self.is_on() {
            return;
        }
        let ts_us = self.now_us();
        self.push(Event {
            name,
            phase: Phase::Counter,
            lane: self.lane,
            worker,
            ts_us,
            dur_us: 0,
            args: EvArgs { value: Some(value), ..args },
        });
    }

    /// Snapshot every shard (events stay in place; stable under
    /// concurrent emitters), sorted by timestamp.
    pub fn snapshot(&self) -> Vec<Event> {
        let shards = self.inner.shards.lock().unwrap().clone();
        let mut all = Vec::new();
        for s in &shards {
            all.extend(s.events.lock().unwrap().iter().cloned());
        }
        all.sort_by_key(|e| (e.ts_us, e.lane, e.worker));
        all
    }

    /// Drain every shard (leaves them empty), sorted by timestamp.
    pub fn drain(&self) -> Vec<Event> {
        let shards = self.inner.shards.lock().unwrap().clone();
        let mut all = Vec::new();
        for s in &shards {
            all.append(&mut s.events.lock().unwrap());
        }
        all.sort_by_key(|e| (e.ts_us, e.lane, e.worker));
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_bus_records_nothing() {
        let t = Telemetry::off();
        assert!(!t.is_on());
        t.instant("enqueue", worker::DRIVER, EvArgs::req(1));
        let s = t.now_us();
        t.span("load", worker::loader(0), s, EvArgs::stage(3));
        t.counter("mem_high_water", worker::DRIVER, 42.0, EvArgs::default());
        assert!(t.drain().is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn events_carry_lane_and_args() {
        let t = Telemetry::on().with_lane(2);
        t.instant("shed", worker::DRIVER, EvArgs::req(7).with_reason("shed_overload"));
        let start = t.now_us();
        t.span("compute", worker::INFER, start, EvArgs::stage(1).with_pass(4).with_epoch(1));
        let evs = t.drain();
        assert_eq!(evs.len(), 2);
        assert!(evs.iter().all(|e| e.lane == 2));
        let shed = evs.iter().find(|e| e.name == "shed").unwrap();
        assert_eq!(shed.args.reason, Some("shed_overload"));
        assert_eq!(shed.args.req, Some(7));
        let comp = evs.iter().find(|e| e.name == "compute").unwrap();
        assert_eq!(comp.phase, Phase::Complete);
        assert_eq!(comp.args.pass, Some(4));
    }

    #[test]
    fn full_shard_drops_and_counts() {
        let t = Telemetry::with_capacity(4);
        for i in 0..10 {
            t.instant("e", worker::DRIVER, EvArgs::req(i));
        }
        assert_eq!(t.dropped(), 6);
        assert_eq!(t.drain().len(), 4);
    }

    #[test]
    fn shards_merge_across_threads_sorted() {
        let t = Telemetry::on();
        let mut handles = Vec::new();
        for w in 0..4u32 {
            let tc = t.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50u64 {
                    tc.instant("tick", w, EvArgs::req(i));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let evs = t.drain();
        assert_eq!(evs.len(), 200);
        assert!(evs.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
        // second drain is empty; shards stay registered
        t.instant("late", worker::DRIVER, EvArgs::default());
        assert_eq!(t.drain().len(), 1);
    }

    #[test]
    fn snapshot_does_not_drain() {
        let t = Telemetry::on();
        t.instant("a", 0, EvArgs::default());
        assert_eq!(t.snapshot().len(), 1);
        assert_eq!(t.snapshot().len(), 1);
        assert_eq!(t.drain().len(), 1);
        assert!(t.snapshot().is_empty());
    }

    #[test]
    fn subscriber_sees_events_without_draining_shards() {
        let t = Telemetry::on();
        let sub = t.subscribe("test", 64);
        t.instant("enqueue", worker::DRIVER, EvArgs::req(1));
        t.instant("retire", worker::DRIVER, EvArgs::req(1));
        let seen = sub.drain();
        assert_eq!(seen.len(), 2);
        assert_eq!(seen[0].name, "enqueue");
        // the shard copy is untouched by subscriber drains
        assert_eq!(t.drain().len(), 2);
        assert_eq!(sub.dropped(), 0);
    }

    #[test]
    fn slow_subscriber_drops_and_counts_without_stalling_emitters() {
        let t = Telemetry::on();
        let sub = t.subscribe("slow", 3);
        for i in 0..10 {
            t.instant("e", worker::DRIVER, EvArgs::req(i));
        }
        // the ring kept its cap, counted the misses, and the bus shards
        // recorded everything — the emitter never noticed
        assert_eq!(sub.drain().len(), 3);
        assert_eq!(sub.dropped(), 7);
        assert_eq!(t.drain().len(), 10);
        assert_eq!(t.dropped(), 0);
        assert_eq!(t.subscriber_drops(), vec![("slow".to_string(), 7)]);
    }

    #[test]
    fn dropped_subscription_detaches() {
        let t = Telemetry::on();
        let sub = t.subscribe("gone", 8);
        t.instant("a", worker::DRIVER, EvArgs::default());
        drop(sub);
        // next fan-out prunes the dead weak ref; no crash, no leak
        t.instant("b", worker::DRIVER, EvArgs::default());
        assert!(t.subscriber_drops().is_empty());
        assert_eq!(t.drain().len(), 2);
    }

    #[test]
    fn two_buses_do_not_cross_talk() {
        let a = Telemetry::on();
        let b = Telemetry::on();
        a.instant("a", 0, EvArgs::default());
        b.instant("b", 0, EvArgs::default());
        let ea = a.drain();
        let eb = b.drain();
        assert_eq!(ea.len(), 1);
        assert_eq!(eb.len(), 1);
        assert_eq!(ea[0].name, "a");
        assert_eq!(eb[0].name, "b");
    }
}
