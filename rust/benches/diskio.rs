//! Micro-benchmarks for the edge-storage simulator: throughput accuracy of
//! the per-stream throttle, parallel-stream scaling toward the aggregate
//! cap (the property PIPELOAD's multi-agent loading relies on), and the
//! token bucket's overhead on unthrottled reads.

use std::io::Write;

use hermes::config::Paths;
use hermes::diskio::{Disk, DiskProfile};
use hermes::util::bench::Bencher;

fn tmpfile(tag: &str, bytes: usize) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("hermes_bench_diskio");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{tag}_{bytes}.bin"));
    if !path.exists() {
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(&vec![0x5A; bytes]).unwrap();
    }
    path
}

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();
    let paths = Paths::detect();
    let one_mb = tmpfile("1mb", 1_000_000);

    b.bench("raw read 1 MB (unthrottled)", || {
        let disk = Disk::preset("unthrottled").unwrap();
        std::hint::black_box(disk.read_file(&one_mb).unwrap());
    });

    // throttle accuracy: 1 MB at 100 MB/s should take ~10 ms
    let disk = Disk::new(DiskProfile::custom(100_000_000, 0, 0));
    let median_ns = b
        .bench("throttled read 1 MB @ 100 MB/s (ideal 10 ms)", || {
            std::hint::black_box(disk.read_file(&one_mb).unwrap());
        })
        .median_ns;
    let err = (median_ns / 1e6 - 10.0).abs() / 10.0;
    println!("  -> throttle error vs ideal: {:.1}%", err * 100.0);

    // parallel scaling: 4 streams under a wide aggregate cap
    for streams in [1usize, 2, 4] {
        let files: Vec<_> = (0..streams).map(|i| tmpfile(&format!("p{i}"), 500_000)).collect();
        let disk = Disk::new(DiskProfile::custom(50_000_000, 400_000_000, 0));
        b.bench(&format!("{streams} parallel streams x 500 KB @ 50 MB/s each"), || {
            std::thread::scope(|s| {
                for f in &files {
                    let d = disk.clone();
                    s.spawn(move || d.read_file(f).unwrap());
                }
            });
        });
    }

    // aggregate cap: 4 streams but medium tops out at 60 MB/s total
    let files: Vec<_> = (0..4).map(|i| tmpfile(&format!("a{i}"), 500_000)).collect();
    let disk = Disk::new(DiskProfile::custom(50_000_000, 60_000_000, 0));
    b.bench("4 streams capped at 60 MB/s aggregate (2 MB total, ideal ~33 ms)", || {
        std::thread::scope(|s| {
            for f in &files {
                let d = disk.clone();
                s.spawn(move || d.read_file(f).unwrap());
            }
        });
    });

    b.dump_json(&paths.results.join("bench_diskio.json"))?;
    Ok(())
}
