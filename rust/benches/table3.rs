//! Bench target: Table III — peak memory footprints for the same sweep as
//! table2 (cached), with ratios vs the non-pipeline baseline.

use hermes::engine::Engine;
use hermes::report;

fn main() -> anyhow::Result<()> {
    let engine = Engine::with_default_paths()?;
    let disk = std::env::var("HERMES_BENCH_DISK").unwrap_or_else(|_| "edge-emmc".into());
    let tokens: Option<usize> =
        std::env::var("HERMES_BENCH_TOKENS").ok().and_then(|s| s.parse().ok()).or(Some(4));
    let fresh = std::env::var("HERMES_BENCH_FRESH").is_ok();
    let agents = [2usize, 4, 6];
    let reports = report::sweep_table23(&engine, &disk, &agents, tokens, fresh)?;
    println!("{}", report::table3(&reports, &agents));
    println!("paper Table III shape targets:");
    println!("  - PipeSwitch ratio ~1.0 (keeps the whole model resident)");
    println!("  - PIPELOAD ratio far below 1, growing ~one layer per extra LA");
    Ok(())
}
