//! Bench target: Table II — end-to-end latency for Baseline / PipeSwitch /
//! PIPELOAD{2,4,6} across the four paper models, with speedups.
//!
//! Shares one sweep with table3 (cached under results/).  Environment:
//!   HERMES_BENCH_DISK    storage preset (default edge-emmc)
//!   HERMES_BENCH_TOKENS  generated tokens for GPT models (default 4)
//!   HERMES_BENCH_FRESH   ignore the cached sweep

use hermes::engine::Engine;
use hermes::report;

fn main() -> anyhow::Result<()> {
    let engine = Engine::with_default_paths()?;
    let disk = std::env::var("HERMES_BENCH_DISK").unwrap_or_else(|_| "edge-emmc".into());
    let tokens: Option<usize> =
        std::env::var("HERMES_BENCH_TOKENS").ok().and_then(|s| s.parse().ok()).or(Some(4));
    let fresh = std::env::var("HERMES_BENCH_FRESH").is_ok();
    let agents = [2usize, 4, 6];
    let reports = report::sweep_table23(&engine, &disk, &agents, tokens, fresh)?;
    println!("{}", report::table2(&reports, &agents));
    println!("paper Table II shape targets:");
    println!("  - BERT/ViT: PIPELOAD beats PipeSwitch, speedup grows with #LAs");
    println!("  - GPT-2/GPT-J: pipelines < baseline at few LAs (per-token reload),");
    println!("    recovering toward/past 1.0 at 6 LAs");
    Ok(())
}
