//! Bench target: whole-pipeline passes — PIPELOAD agent scaling, mode
//! comparison, and coordination overhead (unthrottled disk isolates the
//! L3 machinery from storage time).

use hermes::config::Paths;
use hermes::diskio::Disk;
use hermes::engine::{make_input, WEIGHTS_SEED};
use hermes::pipeload::{run_pipeline, ExecCtx, PipelineOpts};
use hermes::runtime::Runtime;
use hermes::util::bench::Bencher;
use hermes::weights::gen::gen_profile_weights;

fn main() -> anyhow::Result<()> {
    let paths = Paths::detect();
    let rt = Runtime::new(&paths.artifacts)?;
    let mut b = Bencher::new();

    // coordination overhead on a tiny model, storage free
    {
        let p = rt.profile("tiny-bert")?;
        gen_profile_weights(p, &paths.weights, WEIGHTS_SEED, 0.05, false)?;
        rt.prepare(p)?;
        let (input, _, _) = make_input(p, 1, 1);
        for agents in [1usize, 2, 4] {
            let ctx = ExecCtx::new(&rt, "tiny-bert", &paths.weights, Disk::preset("unthrottled")?)?;
            b.bench(&format!("pipeload tiny-bert m={agents} (unthrottled)"), || {
                std::hint::black_box(
                    run_pipeline(&ctx, &PipelineOpts::pipeload(agents), None, &input).unwrap(),
                );
            });
        }
        let ctx = ExecCtx::new(&rt, "tiny-bert", &paths.weights, Disk::preset("unthrottled")?)?;
        b.bench("pipeswitch tiny-bert (unthrottled)", || {
            std::hint::black_box(
                run_pipeline(&ctx, &PipelineOpts::pipeswitch(), None, &input).unwrap(),
            );
        });
    }

    // agent scaling on the paper's BERT profile over simulated eMMC
    {
        let p = rt.profile("bert-large-sim")?;
        gen_profile_weights(p, &paths.weights, WEIGHTS_SEED, 0.05, false)?;
        rt.prepare(p)?;
        let (input, _, _) = make_input(p, 1, 1);
        for agents in [1usize, 2, 4, 6] {
            let ctx = ExecCtx::new(&rt, "bert-large-sim", &paths.weights, Disk::preset("edge-emmc")?)?;
            let (_, d) = b.once(&format!("pipeload bert-large-sim m={agents} (edge-emmc)"), || {
                run_pipeline(&ctx, &PipelineOpts::pipeload(agents), None, &input).unwrap()
            });
            let _ = d;
        }
    }

    b.dump_json(&paths.results.join("bench_pipeline.json"))?;
    Ok(())
}
