//! Bench target: Fig 3 (load vs compute decomposition), Fig 1b (pipeline
//! stall Gantt + idle fraction), and Fig 7 (latency + optimal #LAs vs
//! memory budget).
//!
//! Fig 7's empirical planner pre-runs are the expensive part; restrict the
//! model set with HERMES_BENCH_FIG7_MODELS (comma-separated) or skip with
//! HERMES_BENCH_SKIP_FIG7=1.

use hermes::engine::Engine;
use hermes::report;

fn main() -> anyhow::Result<()> {
    let engine = Engine::with_default_paths()?;
    let disk = std::env::var("HERMES_BENCH_DISK").unwrap_or_else(|_| "edge-emmc".into());

    println!("{}", report::fig3(&engine, &disk)?);
    println!("{}", report::fig1b(&engine, &disk, "bert-large-sim")?);

    if std::env::var("HERMES_BENCH_SKIP_FIG7").is_err() {
        println!("{}", report::fig7(&engine, &disk, &[0.15, 0.25, 0.4, 0.6, 0.8], 8)?);
    } else {
        println!("(fig 7 skipped via HERMES_BENCH_SKIP_FIG7)");
    }
    Ok(())
}
