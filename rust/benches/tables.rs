//! Bench target: Table I + Fig 2 (manifest-derived, cheap) — prints the
//! paper's model-configuration table and memory-decomposition figure.

use hermes::engine::Engine;
use hermes::report;

fn main() -> anyhow::Result<()> {
    let engine = Engine::with_default_paths()?;
    println!("{}", report::table1(&engine)?);
    println!("{}", report::fig2(&engine)?);
    Ok(())
}
