//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. destroy-after-compute ON vs OFF (the paper's core memory mechanism) —
//!    latency cost vs peak-memory saving on a throttled disk;
//! 2. shard checksum validation ON vs OFF — integrity overhead on the
//!    loading path;
//! 3. per-token weight reload vs resident weights for generative decode —
//!    the paper's §VII future-work direction quantified (a KV-cache-style
//!    persistent-weights engine is what the reload loses against);
//! 4. round-robin assignment vs single agent — stall accounting.

use hermes::config::{Mode, Paths, RunConfig};
use hermes::diskio::Disk;
use hermes::engine::{make_input, Engine, WEIGHTS_SEED};
use hermes::pipeload::{run_pipeline, ExecCtx, PipelineOpts};
use hermes::util::bench::Bencher;
use hermes::util::human_bytes;
use hermes::weights::gen::gen_profile_weights;

fn main() -> anyhow::Result<()> {
    let paths = Paths::detect();
    let engine = Engine::with_default_paths()?;
    let rt = &engine.runtime;
    let mut b = Bencher::new();
    let model = "bert-large-sim";
    let p = rt.profile(model)?;
    gen_profile_weights(p, &paths.weights, WEIGHTS_SEED, 0.05, false)?;
    rt.prepare(p)?;
    let (input, _, _) = make_input(p, 1, 1);

    // 1. destroy ON vs OFF
    println!("-- ablation 1: destroy-after-compute (m=4, edge-emmc) --");
    for destroy in [true, false] {
        let ctx = ExecCtx::new(rt, model, &paths.weights, Disk::preset("edge-emmc")?)?;
        let opts = PipelineOpts {
            agents: 4,
            destroy_after_compute: destroy,
            validate_shards: false,
        };
        let ((_, stats), _) = b.once(&format!("destroy={destroy}"), || {
            run_pipeline(&ctx, &opts, None, &input).unwrap()
        });
        println!("    peak memory: {}", human_bytes(stats.peak_bytes));
    }

    // 2. checksum validation overhead
    println!("-- ablation 2: shard validation (m=4, unthrottled) --");
    for validate in [false, true] {
        let ctx = ExecCtx::new(rt, model, &paths.weights, Disk::preset("unthrottled")?)?;
        let opts = PipelineOpts { agents: 4, destroy_after_compute: true, validate_shards: validate };
        b.bench(&format!("validate_shards={validate}"), || {
            std::hint::black_box(run_pipeline(&ctx, &opts, None, &input).unwrap());
        });
    }

    // 3. per-token reload (paper semantics) vs resident weights
    println!("-- ablation 3: generative decode, reload vs resident (gpt2-base-sim, 4 tokens) --");
    for (label, mode) in [("pipeload reload/token", Mode::PipeLoad), ("baseline resident", Mode::Baseline)] {
        let cfg = RunConfig {
            profile: "gpt2-base-sim".into(),
            mode,
            agents: 4,
            disk: "edge-emmc".into(),
            gen_tokens: Some(4),
            ..RunConfig::default()
        };
        let (rep, _) = b.once(label, || engine.run(&cfg).unwrap()).0;
        println!("    peak {}  (latency {:.1} ms)", human_bytes(rep.peak_bytes), rep.latency_ms);
    }

    // 4. stall accounting: 1 agent vs 6 agents on slow storage
    println!("-- ablation 4: wait-stall vs agent count (edge-sd) --");
    for agents in [1usize, 6] {
        let ctx = ExecCtx::new(rt, model, &paths.weights, Disk::preset("edge-sd")?)?;
        let ((_, stats), _) = b.once(&format!("m={agents} on edge-sd"), || {
            run_pipeline(&ctx, &PipelineOpts::pipeload(agents), None, &input).unwrap()
        });
        println!(
            "    inference wait-stall: {:.1} ms, load total: {:.1} ms",
            stats.wait_stall_ms, stats.load_ms_total
        );
    }

    b.dump_json(&paths.results.join("bench_ablation.json"))?;
    Ok(())
}
