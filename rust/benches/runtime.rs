//! Micro-benchmarks for the L3 hot path: literal construction from shard
//! bytes, per-layer execution, executable-cache hits (§Perf, DESIGN.md §8).

use hermes::config::Paths;
use hermes::engine::{make_input, WEIGHTS_SEED};
use hermes::runtime::{literal_from_tensor, Runtime};
use hermes::util::bench::Bencher;
use hermes::weights::gen::gen_profile_weights;
use hermes::weights::read_shard;

fn main() -> anyhow::Result<()> {
    let paths = Paths::detect();
    let rt = Runtime::new(&paths.artifacts)?;
    let mut b = Bencher::new();

    for name in ["tiny-bert", "bert-large-sim"] {
        let p = rt.profile(name)?;
        gen_profile_weights(p, &paths.weights, WEIGHTS_SEED, 0.05, false)?;
        let stage = &p.stages[1];
        let shard = read_shard(&paths.weights.join(name).join(&stage.shard))?;
        let entry = p.entry(&stage.kind, 1)?;
        rt.prepare(p)?;

        let mb = shard.total_data_bytes() as f64 / (1024.0 * 1024.0);
        b.bench(&format!("literal_from_tensor {name} ({mb:.1} MiB)"), || {
            for t in &shard.tensors {
                std::hint::black_box(literal_from_tensor(t).unwrap());
            }
        });

        let (input, _, _) = make_input(p, 1, 1);
        let first_entry = p.entry(&p.stages[0].kind, 1)?;
        let x0 = input.to_buffer(&rt, &first_entry.activations[0])?;
        let shard0 = read_shard(&paths.weights.join(name).join(&p.stages[0].shard))?;
        let act = rt.execute_entry(p, first_entry, &[&x0], &shard0)?;

        b.bench(&format!("execute {} {name}", stage.kind), || {
            std::hint::black_box(rt.execute_entry(p, entry, &[&act], &shard).unwrap());
        });
        b.bench(&format!("weight upload {} {name}", stage.kind), || {
            for t in &shard.tensors {
                std::hint::black_box(rt.buffer_from_tensor(t).unwrap());
            }
        });

        b.bench(&format!("executable cache hit {name}"), || {
            std::hint::black_box(rt.executable(p, entry).unwrap());
        });
    }
    b.dump_json(&paths.results.join("bench_runtime.json"))?;
    Ok(())
}
