# Hermes build drivers.
#
# `make artifacts` runs the python AOT step (jax -> HLO text + manifest +
# golden vectors) into rust/artifacts — the Rust crate's single source of
# truth.  Everything after that is pure Rust (tier-1: `make test`).

PY ?= python3

.PHONY: artifacts golden build test examples bench bench-diff tsan fmt clippy clean

artifacts:
	cd python && $(PY) -m compile.aot --out-dir ../rust/artifacts

golden:
	cd python && $(PY) -m compile.aot --out-dir ../rust/artifacts --golden-only

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

examples:
	cargo build --release --examples

# Record perf trajectories (one-model kv off/on, the concurrent two-lane
# router run, the bursty shared-prompt workload measured fixed-batch AND
# continuous, an elastic shrink-grow run, and a pinned gpt2-base-sim
# overlapped decode) into BENCH_pr6.json + BENCH_pr7.json; CI uploads both.
bench:
	cargo run --release --example bench_trajectory

# Fail-soft per-metric deltas between the PR 6 and PR 7 trajectories
# (advisory: a missing file prints a note instead of failing the build).
# NOTE: one `make bench` run writes both files from the same summaries, so
# most sections diff to zero by construction — the signal is the
# `continuous_burst` section (fixed-batch vs continuous scheduling, incl.
# `tokens_per_sec` / `slo_attained_pct` / `kv_dedup_bytes`) plus whatever
# a previous CI run's BENCH_pr6 artifact contributes when dropped in place.
bench-diff:
	$(PY) scripts/bench_diff.py BENCH_pr6.json BENCH_pr7.json

# ThreadSanitizer over the concurrency-heavy test binaries (nightly-only:
# -Zsanitizer needs -Zbuild-std so std is instrumented too).  PJRT-backed
# integration tests are excluded — the C runtime is not TSan-clean — so
# this sweeps the pure-Rust ledgers, gates, governor, and property tests.
TSAN_TARGET ?= $(shell rustc -vV | sed -n 's/^host: //p')
tsan:
	RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" \
	cargo +nightly test -Zbuild-std --target $(TSAN_TARGET) -q \
		--lib -p hermes -- memory:: pipeload::gate server::lanes
	RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" \
	cargo +nightly test -Zbuild-std --target $(TSAN_TARGET) -q \
		--test prop_invariants -- concurrent

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

clean:
	cargo clean
	rm -rf rust/weights rust/results
