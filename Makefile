# Hermes build drivers.
#
# `make artifacts` runs the python AOT step (jax -> HLO text + manifest +
# golden vectors) into rust/artifacts — the Rust crate's single source of
# truth.  Everything after that is pure Rust (tier-1: `make test`).

PY ?= python3

.PHONY: artifacts golden build test examples bench bench-diff trace-smoke analyze-smoke chaos-smoke tsan fmt clippy clean

artifacts:
	cd python && $(PY) -m compile.aot --out-dir ../rust/artifacts

golden:
	cd python && $(PY) -m compile.aot --out-dir ../rust/artifacts --golden-only

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

examples:
	cargo build --release --examples

# Record perf trajectories (one-model kv off/on, the concurrent two-lane
# router run, the bursty shared-prompt continuous workload, an elastic
# shrink-grow run with its telemetry-derived accountant high-water
# timeline, and a pinned gpt2-base-sim overlapped decode) into
# BENCH_pr7.json + BENCH_pr8.json + BENCH_pr9.json + BENCH_pr10.json
# (pr9 adds the offline analyzer's `analyze` section; pr10 adds the
# `recovery` section: the same serve run under a transparent fault plan,
# so the recovery cost — retries, injected stalls — is a tracked metric);
# CI uploads all four.
bench:
	cargo run --release --example bench_trajectory

# Fail-soft per-metric deltas between the PR 9 and PR 10 trajectories
# (advisory: a missing file prints a note instead of failing the build).
# NOTE: one `make bench` run writes all files from the same summaries, so
# the shared sections diff to zero by construction — the signal is the
# PR 10-only `recovery` section (faults fired, retries, recovery
# overhead) plus whatever a previous CI run's BENCH_pr9 artifact
# contributes when dropped in place.
bench-diff:
	$(PY) scripts/bench_diff.py BENCH_pr9.json BENCH_pr10.json

# Short continuous serve with the event bus enabled: exports a Chrome
# trace and validates it (well-formed JSON, non-empty, balanced B/E pairs
# per (pid,tid) row).  CI uploads trace_smoke.json next to the bench
# artifacts; load it into https://ui.perfetto.dev to browse.
trace-smoke: build
	./target/release/hermes serve --model tiny-gpt --mode pipeload \
		--disk unthrottled --kv-cache --kv-block-tokens 2 --continuous \
		--requests 4 --max-batch 1 --trace-out trace_smoke.json
	$(PY) scripts/validate_trace.py trace_smoke.json

# Trace -> analyze round trip: the same short continuous serve, then the
# offline analyzer gates on it — every request lifecycle complete, every
# pass's critical path attributed, and ZERO memory-audit drift (`hermes
# analyze` exits nonzero on any analysis error, including dropped events
# and audit drift).
analyze-smoke: build
	./target/release/hermes serve --model tiny-gpt --mode pipeload \
		--disk unthrottled --kv-cache --kv-block-tokens 2 --continuous \
		--requests 4 --max-batch 1 --trace-out analyze_smoke.json
	./target/release/hermes analyze analyze_smoke.json

# Chaos smoke: the same short continuous serve under a fixed-seed fault
# plan of TRANSPARENT faults only — disk errors absorbed by the bounded
# load retry, an injected stuck medium, transient accountant refusals —
# so every request still succeeds (`serve` exits nonzero on any
# rejection), then `hermes analyze` gates the recorded trace: complete
# lifecycles and zero memory-audit drift even with the fault plane
# firing.  The destructive faults (agent panics, lane deaths) live in the
# chaos-soak integration test, where a supervisor absorbs them.
chaos-smoke: build
	./target/release/hermes serve --model tiny-gpt --mode pipeload \
		--disk unthrottled --kv-cache --kv-block-tokens 2 --continuous \
		--requests 6 --max-batch 1 --no-device-cache \
		--fault-plan 'seed=42;disk_error@2x2;disk_slow@3+20;acquire_fail@4x2' \
		--trace-out chaos_smoke.json
	./target/release/hermes analyze chaos_smoke.json

# ThreadSanitizer over the concurrency-heavy test binaries (nightly-only:
# -Zsanitizer needs -Zbuild-std so std is instrumented too).  PJRT-backed
# integration tests are excluded — the C runtime is not TSan-clean — so
# this sweeps the pure-Rust ledgers, gates, governor, and property tests.
TSAN_TARGET ?= $(shell rustc -vV | sed -n 's/^host: //p')
tsan:
	RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" \
	cargo +nightly test -Zbuild-std --target $(TSAN_TARGET) -q \
		--lib -p hermes -- memory:: pipeload::gate server::lanes
	RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" \
	cargo +nightly test -Zbuild-std --target $(TSAN_TARGET) -q \
		--test prop_invariants -- concurrent

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

clean:
	cargo clean
	rm -rf rust/weights rust/results
