# Hermes build drivers.
#
# `make artifacts` runs the python AOT step (jax -> HLO text + manifest +
# golden vectors) into rust/artifacts — the Rust crate's single source of
# truth.  Everything after that is pure Rust (tier-1: `make test`).

PY ?= python3

.PHONY: artifacts golden build test examples bench bench-diff fmt clippy clean

artifacts:
	cd python && $(PY) -m compile.aot --out-dir ../rust/artifacts

golden:
	cd python && $(PY) -m compile.aot --out-dir ../rust/artifacts --golden-only

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

examples:
	cargo build --release --examples

# Record serve --json perf trajectories (one-model kv off/on, a two-lane
# router run, and an elastic shrink-grow run) into BENCH_pr3.json (PR 3
# layout, for cross-PR diffing) + BENCH_pr4.json; CI uploads both.
bench:
	cargo run --release --example bench_trajectory

# Fail-soft per-metric deltas between the PR 3 and PR 4 trajectories
# (advisory: a missing file prints a note instead of failing the build).
# NOTE: one `make bench` run writes both files from the same summaries, so
# the shared sections diff to zero by construction — the deltas carry
# signal when BENCH_pr3.json comes from an earlier checkout or a previous
# CI run's artifact dropped in place.
bench-diff:
	$(PY) scripts/bench_diff.py BENCH_pr3.json BENCH_pr4.json

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

clean:
	cargo clean
	rm -rf rust/weights rust/results
