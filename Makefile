# Hermes build drivers.
#
# `make artifacts` runs the python AOT step (jax -> HLO text + manifest +
# golden vectors) into rust/artifacts — the Rust crate's single source of
# truth.  Everything after that is pure Rust (tier-1: `make test`).

PY ?= python3

.PHONY: artifacts golden build test examples bench fmt clippy clean

artifacts:
	cd python && $(PY) -m compile.aot --out-dir ../rust/artifacts

golden:
	cd python && $(PY) -m compile.aot --out-dir ../rust/artifacts --golden-only

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

examples:
	cargo build --release --examples

# Record a serve --json perf trajectory (one-model kv off/on + a two-lane
# router run) into BENCH_pr3.json; CI uploads it as a build artifact.
bench:
	cargo run --release --example bench_trajectory

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

clean:
	cargo clean
	rm -rf rust/weights rust/results
