# Hermes build drivers.
#
# `make artifacts` runs the python AOT step (jax -> HLO text + manifest +
# golden vectors) into rust/artifacts — the Rust crate's single source of
# truth.  Everything after that is pure Rust (tier-1: `make test`).

PY ?= python3

.PHONY: artifacts golden build test examples bench bench-diff fmt clippy clean

artifacts:
	cd python && $(PY) -m compile.aot --out-dir ../rust/artifacts

golden:
	cd python && $(PY) -m compile.aot --out-dir ../rust/artifacts --golden-only

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

examples:
	cargo build --release --examples

# Record perf trajectories (one-model kv off/on, a two-lane router run,
# an elastic shrink-grow run, and a pinned gpt2-base-sim decode measured
# with PR 4 semantics AND with the overlapped decode path) into
# BENCH_pr4.json + BENCH_pr5.json; CI uploads both.
bench:
	cargo run --release --example bench_trajectory

# Fail-soft per-metric deltas between the PR 4 and PR 5 trajectories
# (advisory: a missing file prints a note instead of failing the build).
# NOTE: one `make bench` run writes both files from the same summaries, so
# the serve sections diff to zero by construction — the signal is the
# `decode_gpt2_pinned` section (non-overlapped vs overlapped decode) plus
# whatever a previous CI run's BENCH_pr4 artifact contributes when dropped
# in place.
bench-diff:
	$(PY) scripts/bench_diff.py BENCH_pr4.json BENCH_pr5.json

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

clean:
	cargo clean
	rm -rf rust/weights rust/results
