"""L2 model layer tests: shapes, semantics, and config derivations."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import configs, model
from compile.model import KernelChoice

PROFILES = configs.load_profiles()
KC_JNP = KernelChoice(attention=False, layernorm=False, ffn=False)
KC_PALLAS = KernelChoice(attention=True, layernorm=True, ffn=True)


def _weights(p, kind, seed=0):
    return model.make_example_weights(p, kind, np.random.RandomState(seed))


@pytest.mark.parametrize("name", list(PROFILES))
def test_stage_table_structure(name):
    p = PROFILES[name]
    stages = configs.stage_table(p)
    # first stage embeds, last stage is the head, body layers in between
    body = p.layers + (p.decoder_layers if p.family == "bart" else 0)
    assert len(stages) == body + 2
    assert [s["index"] for s in stages] == list(range(len(stages)))
    assert stages[0]["kind"] in ("embedding", "patch_embed")
    assert stages[-1]["kind"] in ("pooler", "classifier", "lm_head")
    # shard names unique
    assert len({s["shard"] for s in stages}) == len(stages)


@pytest.mark.parametrize("name", ["bert-large-sim", "gpt2-base-sim",
                                  "vit-large-sim", "gptj-sim"])
def test_encoder_decoder_layers_dominate_memory(name):
    """Observation I / Fig 2: body layers hold 70-95%+ of total weight bytes."""
    p = PROFILES[name]
    total = configs.profile_total_bytes(p)
    body_kind = {"bert": "encoder_layer", "vit": "encoder_layer",
                 "gpt2": "decoder_layer", "gptj": "gptj_layer"}[p.family]
    body = sum(s.num_bytes() for s in configs.SPEC_FNS[body_kind](p)) * p.layers
    share = body / total
    assert 0.70 <= share <= 0.995, f"{name}: body share {share:.3f}"


@pytest.mark.parametrize("name,kind", [
    ("tiny-bert", "embedding"),
    ("tiny-bert", "encoder_layer"),
    ("tiny-bert", "pooler"),
    ("tiny-gpt", "decoder_layer"),
    ("tiny-gpt", "lm_head"),
    ("tiny-vit", "patch_embed"),
    ("tiny-vit", "classifier"),
    ("tiny-gptj", "gptj_layer"),
])
def test_layer_shapes(name, kind):
    p = PROFILES[name]
    w = _weights(p, kind)
    acts = model.activation_in_specs(p, kind, 1)
    rng = np.random.RandomState(1)
    args = []
    for a in acts:
        if a["dtype"] == "i32":
            args.append(jnp.asarray(rng.randint(0, p.vocab, a["shape"]), jnp.int32))
        else:
            args.append(jnp.asarray(rng.randn(*a["shape"]), jnp.float32))
    out = model.FWD_FNS[kind](p, *args, *w)
    expect = model.activation_out_spec(p, kind, 1)
    assert list(out.shape) == expect["shape"]
    assert np.isfinite(np.asarray(out)).all()


def test_causal_decoder_prefix_stability():
    """Changing ids after position t must not change logits at positions < t.

    This is the property the Rust decode loop relies on: it runs the full
    padded sequence every step and reads logits at cur_len-1.
    """
    p = PROFILES["tiny-gpt"]
    stages = configs.stage_table(p)
    rng = np.random.RandomState(3)
    weights = [model.make_example_weights(p, s["kind"], rng) for s in stages]
    ids1 = rng.randint(0, p.vocab, (1, p.max_seq)).astype(np.int32)
    ids2 = ids1.copy()
    ids2[:, 8:] = (ids2[:, 8:] + 7) % p.vocab
    out1 = np.asarray(model.full_forward(p, jnp.asarray(ids1), weights))
    out2 = np.asarray(model.full_forward(p, jnp.asarray(ids2), weights))
    np.testing.assert_allclose(out1[:, :8], out2[:, :8], rtol=1e-4, atol=1e-5)
    assert not np.allclose(out1[:, 8:], out2[:, 8:])


@pytest.mark.parametrize("name", ["tiny-bert", "tiny-gpt", "tiny-gptj"])
def test_pallas_vs_jnp_full_model(name):
    """Full forward with all Pallas kernels == full forward with plain jnp."""
    p = PROFILES[name]
    stages = configs.stage_table(p)
    rng = np.random.RandomState(5)
    weights = [model.make_example_weights(p, s["kind"], rng) for s in stages]
    ids = jnp.asarray(rng.randint(0, p.vocab, (1, p.max_seq)), jnp.int32)
    a = np.asarray(model.full_forward(p, ids, weights, kc=KC_PALLAS))
    b = np.asarray(model.full_forward(p, ids, weights, kc=KC_JNP))
    np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)


def test_gptj_parallel_structure():
    """GPT-J block: attn and FFN read the same LN(x), not sequential."""
    p = PROFILES["tiny-gptj"]
    w = _weights(p, "gptj_layer")
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(1, p.max_seq, p.hidden), jnp.float32)
    out = model.gptj_layer_fwd(p, x, *w)
    # zeroing the FFN weights must still leave the attention contribution
    w2 = list(w)
    w2[6] = jnp.zeros_like(w2[6]); w2[7] = jnp.zeros_like(w2[7])
    w2[8] = jnp.zeros_like(w2[8]); w2[9] = jnp.zeros_like(w2[9])
    out_noffn = model.gptj_layer_fwd(p, x, *w2)
    assert not np.allclose(np.asarray(out), np.asarray(out_noffn))
    # with attention AND ffn zeroed, block is identity
    w3 = [jnp.zeros_like(t) for t in w]
    out_id = model.gptj_layer_fwd(p, x, *w3)
    np.testing.assert_allclose(np.asarray(out_id), np.asarray(x), atol=1e-6)


def test_table1_shares_sane():
    """Sim profiles keep the paper's Fig-2 ordering: ViT/GPT-J most body-heavy."""
    share = {}
    for n in ["bert-large-sim", "vit-large-sim", "gpt2-base-sim", "gptj-sim"]:
        p = PROFILES[n]
        body_kind = {"bert": "encoder_layer", "vit": "encoder_layer",
                     "gpt2": "decoder_layer", "gptj": "gptj_layer"}[p.family]
        body = sum(s.num_bytes() for s in configs.SPEC_FNS[body_kind](p)) * p.layers
        share[n] = body / configs.profile_total_bytes(p)
    assert share["vit-large-sim"] > share["bert-large-sim"]
    assert share["gptj-sim"] > share["gpt2-base-sim"]


@pytest.mark.parametrize("name", ["tiny-gpt", "tiny-gptj"])
@pytest.mark.parametrize("batch", [1, 2])
def test_incremental_decode_matches_full_recompute(name, batch):
    """The *_inc/_kv entries' math: greedy decode with a KV cache must pick
    bit-identical tokens to per-token full-prefix recompute (the Rust
    kvcache subsystem's correctness contract)."""
    import jax

    p = PROFILES[name]
    body = "decoder_layer" if p.family == "gpt2" else "gptj_layer"
    stages = configs.stage_table(p)
    rng = np.random.RandomState(11)
    weights = [model.make_example_weights(p, s["kind"], rng) for s in stages]
    B, S, H = batch, p.max_seq, p.hidden
    prompt, gen = p.prompt_tokens, 6
    ids = np.zeros((B, S), dtype=np.int32)
    ids[:, :prompt] = rng.randint(1, p.vocab, size=(B, prompt))

    def full_logits(cur_ids, cur):
        out = model.full_forward(p, jnp.asarray(cur_ids), weights)
        return np.asarray(out)[:, cur - 1, :]

    # reference: full recompute every token
    ref_ids, cur, ref = ids.copy(), prompt, []
    for _ in range(gen):
        nxt = full_logits(ref_ids, cur).argmax(axis=-1)
        ref.append(nxt)
        ref_ids[:, cur] = nxt
        cur += 1

    # KV path: one full pass primes the cache, then incremental passes
    body_idx = [i for i, s in enumerate(stages) if s["kind"] == body]
    k_cache = {i: np.zeros((B, S, H), np.float32) for i in body_idx}
    v_cache = {i: np.zeros((B, S, H), np.float32) for i in body_idx}
    kv_ids, cur, got = ids.copy(), prompt, []
    x = jnp.asarray(kv_ids)
    for si, st in enumerate(stages):
        if st["kind"] == body:
            kv = np.asarray(model.FWD_FNS[body + "_kv"](p, x, *weights[si]))
            k_cache[si][:, :cur, :] = kv[:, :cur, :]
            v_cache[si][:, :cur, :] = kv[:, S:S + cur, :]
        x = model.FWD_FNS[st["kind"]](p, x, *weights[si])
    nxt = np.asarray(x)[:, cur - 1, :].argmax(axis=-1)
    got.append(nxt)
    kv_ids[:, cur] = nxt
    cur += 1
    for _ in range(gen - 1):
        pos = cur - 1
        posb = jnp.asarray([pos], jnp.int32)
        x = model.embedding_inc_fwd(p, jnp.asarray(kv_ids[:, pos:pos + 1]),
                                    posb, *weights[0])
        for si in body_idx:
            out = np.asarray(model.FWD_FNS[body + "_inc"](
                p, x, jnp.asarray(k_cache[si]), jnp.asarray(v_cache[si]),
                posb, *weights[si]))
            x = jnp.asarray(out[:, 0:1, :])
            k_cache[si][:, pos, :] = out[:, 1, :]
            v_cache[si][:, pos, :] = out[:, 2, :]
        logits = np.asarray(model.FWD_FNS["lm_head"](p, x, *weights[-1]))[:, 0, :]
        nxt = logits.argmax(axis=-1)
        got.append(nxt)
        kv_ids[:, cur] = nxt
        cur += 1

    assert (np.array(ref) == np.array(got)).all()
