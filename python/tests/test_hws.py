"""Shard format round-trip + corruption detection (python side).

Cross-language interop is covered by rust/tests/golden_numerics.rs, which
reads shards written here.
"""

import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import hws


def test_roundtrip_basic(tmp_path):
    path = str(tmp_path / "s.hws")
    tensors = [
        ("w", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("b", np.array([1.5, -2.5], dtype=np.float32)),
        ("ids", np.array([[1, 2], [3, 4]], dtype=np.int32)),
    ]
    n = hws.write_shard(path, "encoder_layer", 7, tensors)
    assert os.path.getsize(path) == n
    kind, stage, got = hws.read_shard(path)
    assert kind == "encoder_layer" and stage == 7
    assert len(got) == 3
    for (en, ea), (gn, ga) in zip(tensors, got):
        assert en == gn and ea.dtype == ga.dtype
        np.testing.assert_array_equal(ea, ga)


@settings(max_examples=20, deadline=None)
@given(
    n_tensors=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_random(tmp_path_factory, n_tensors, seed):
    rng = np.random.RandomState(seed)
    tmp = tmp_path_factory.mktemp("hws")
    tensors = []
    for i in range(n_tensors):
        ndim = rng.randint(1, 4)
        shape = tuple(int(rng.randint(1, 8)) for _ in range(ndim))
        dt = [np.float32, np.int32, np.float16][rng.randint(0, 3)]
        arr = (rng.randn(*shape) * 10).astype(dt)
        tensors.append((f"t{i}", arr))
    path = str(tmp / f"r{seed}.hws")
    hws.write_shard(path, "k", seed % 1000, tensors)
    _, _, got = hws.read_shard(path)
    for (en, ea), (gn, ga) in zip(tensors, got):
        np.testing.assert_array_equal(ea, ga)


def test_checksum_detects_corruption(tmp_path):
    path = str(tmp_path / "c.hws")
    hws.write_shard(path, "k", 0, [("w", np.ones(64, dtype=np.float32))])
    data = bytearray(open(path, "rb").read())
    data[50] ^= 0xFF  # flip a data byte
    open(path, "wb").write(bytes(data))
    with pytest.raises(ValueError, match="checksum"):
        hws.read_shard(path)


def test_empty_tensor_list(tmp_path):
    path = str(tmp_path / "e.hws")
    hws.write_shard(path, "k", 1, [])
    kind, stage, got = hws.read_shard(path)
    assert kind == "k" and stage == 1 and got == []


def test_fletcher64_known_values():
    assert hws.fletcher64(b"") == 0
    a = hws.fletcher64(b"abcdefgh")
    b = hws.fletcher64(b"abcdefgi")
    assert a != b
    # padding: 5 bytes pads to 8 with zeros -> differs from raw 8 zeros case
    assert hws.fletcher64(b"\x01") == hws.fletcher64(b"\x01\x00\x00\x00")
