"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (and block sizes) and asserts allclose — this is
the CORE correctness signal for the kernel layer.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import attention as attn_k
from compile.kernels import ffn as ffn_k
from compile.kernels import layernorm as ln_k
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.randn(*shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    bh=st.integers(1, 6),
    seq=st.sampled_from([4, 8, 10, 16, 32, 50, 64]),
    dh=st.sampled_from([4, 8, 16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(bh, seq, dh, causal, seed):
    rng = np.random.RandomState(seed)
    q, k, v = (_rand(rng, bh, seq, dh) for _ in range(3))
    out = attn_k.attention(q, k, v, causal=causal)
    exp = ref.attention_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    seq=st.sampled_from([16, 32, 64]),
    bq=st.sampled_from([4, 8, 16]),
    bk=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_block_size_invariance(seq, bq, bk, causal, seed):
    """Output must not depend on the chosen tiling."""
    rng = np.random.RandomState(seed)
    q, k, v = (_rand(rng, 2, seq, 8) for _ in range(3))
    a = attn_k.attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    b = attn_k.attention(q, k, v, causal=causal, block_q=seq, block_k=seq)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_attention_causality():
    """Changing future keys must not change past outputs under causal mask."""
    rng = np.random.RandomState(0)
    q, k, v = (_rand(rng, 1, 16, 8) for _ in range(3))
    out1 = np.asarray(attn_k.attention(q, k, v, causal=True))
    k2 = k.at[:, 8:, :].set(99.0)
    v2 = v.at[:, 8:, :].set(-99.0)
    out2 = np.asarray(attn_k.attention(q, k2, v2, causal=True))
    np.testing.assert_allclose(out1[:, :8], out2[:, :8], rtol=1e-5, atol=1e-6)
    assert not np.allclose(out1[:, 8:], out2[:, 8:])


def test_attention_softmax_stability():
    """Large score magnitudes must not overflow (online softmax)."""
    rng = np.random.RandomState(1)
    q = _rand(rng, 1, 32, 8, scale=30.0)
    k = _rand(rng, 1, 32, 8, scale=30.0)
    v = _rand(rng, 1, 32, 8)
    out = np.asarray(attn_k.attention(q, k, v, causal=False))
    assert np.isfinite(out).all()
    exp = np.asarray(ref.attention_ref(q, k, v, False))
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


def test_vmem_footprint_estimate_monotone():
    small = attn_k.vmem_footprint_bytes(64, 32, block_q=8, block_k=8)
    big = attn_k.vmem_footprint_bytes(64, 32, block_q=32, block_k=32)
    assert small < big
    # default tiling of a bert-large-sim layer fits a 16 MiB VMEM budget
    assert attn_k.vmem_footprint_bytes(64, 32) < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    rows=st.sampled_from([1, 2, 8, 50, 64]),
    h=st.sampled_from([8, 32, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(rows, h, seed):
    rng = np.random.RandomState(seed)
    x = _rand(rng, rows, h, scale=3.0)
    g = _rand(rng, h, scale=0.5) + 1.0
    b = _rand(rng, h, scale=0.5)
    out = ln_k.layernorm(x, g, b)
    exp = ref.layernorm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)


def test_layernorm_zero_variance_row():
    x = jnp.ones((4, 16), jnp.float32) * 5.0
    g = jnp.ones((16,), jnp.float32)
    b = jnp.zeros((16,), jnp.float32)
    out = np.asarray(ln_k.layernorm(x, g, b))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, 0.0, atol=1e-3)


# ---------------------------------------------------------------------------
# ffn
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    rows=st.sampled_from([1, 4, 16, 50]),
    h=st.sampled_from([8, 32]),
    f=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ffn_matches_ref(rows, h, f, seed):
    rng = np.random.RandomState(seed)
    x = _rand(rng, rows, h)
    w1, b1 = _rand(rng, h, f, scale=0.2), _rand(rng, f, scale=0.2)
    w2, b2 = _rand(rng, f, h, scale=0.2), _rand(rng, h, scale=0.2)
    out = ffn_k.ffn(x, w1, b1, w2, b2)
    exp = ref.ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)


def test_block_size_picker():
    assert attn_k._largest_divisor_leq(64, 32) == 32
    assert attn_k._largest_divisor_leq(50, 32) == 25
    assert attn_k._largest_divisor_leq(10, 32) == 10
    assert attn_k._largest_divisor_leq(7, 4) == 1
