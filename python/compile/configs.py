"""Model profile registry (build-time).

Single source of truth for architecture dims is ``configs/models.json`` at
the repo root; this module loads it and derives the per-layer-kind tensor
specs (ordered parameter lists with names / shapes / dtypes) that both
``model.py`` (L2 forward fns) and ``aot.py`` (manifest emission) consume.

The Rust side never re-derives these specs: it reads them from
``artifacts/manifest.json`` written by ``aot.py``, so the two languages
cannot drift.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", ".."))
MODELS_JSON = os.path.join(REPO_ROOT, "configs", "models.json")


@dataclass(frozen=True)
class TensorSpec:
    """One weight tensor inside a layer shard (ordered)."""

    name: str
    shape: Tuple[int, ...]
    dtype: str = "f32"

    def num_elements(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    def num_bytes(self) -> int:
        size = {"f32": 4, "i32": 4, "u32": 4, "f16": 2}[self.dtype]
        return self.num_elements() * size

    def to_json(self) -> dict:
        return {"name": self.name, "shape": list(self.shape), "dtype": self.dtype}


@dataclass(frozen=True)
class Profile:
    """A model architecture profile (one paper model, scaled)."""

    name: str
    family: str
    arch: str
    hidden: int
    heads: int
    ffn: int
    layers: int
    max_seq: int
    seq: int
    dtype: str
    pre_ln: bool
    vocab: int = 0
    type_vocab: int = 0
    num_classes: int = 0
    patch_dim: int = 0
    decoder_layers: int = 0
    prompt_tokens: int = 0
    gen_tokens: int = 0
    batches: Tuple[int, ...] = (1,)
    paper_model: str = ""
    raw: dict = field(default_factory=dict, compare=False)

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads


def load_profiles(path: str = MODELS_JSON) -> Dict[str, Profile]:
    with open(path) as f:
        doc = json.load(f)
    out: Dict[str, Profile] = {}
    for name, cfg in doc["profiles"].items():
        out[name] = Profile(
            name=name,
            family=cfg["family"],
            arch=cfg["arch"],
            hidden=cfg["hidden"],
            heads=cfg["heads"],
            ffn=cfg["ffn"],
            layers=cfg["layers"],
            max_seq=cfg["max_seq"],
            seq=cfg["seq"],
            dtype=cfg.get("dtype", "f32"),
            pre_ln=cfg.get("pre_ln", False),
            vocab=cfg.get("vocab", 0),
            type_vocab=cfg.get("type_vocab", 0),
            num_classes=cfg.get("num_classes", 0),
            patch_dim=cfg.get("patch_dim", 0),
            decoder_layers=cfg.get("decoder_layers", 0),
            prompt_tokens=cfg.get("prompt_tokens", 0),
            gen_tokens=cfg.get("gen_tokens", 0),
            batches=tuple(cfg.get("batches", [1])),
            paper_model=cfg.get("paper_model", ""),
            raw=cfg,
        )
    return out


# ---------------------------------------------------------------------------
# Per-layer-kind tensor specs.  Order matters: it is both the HLO parameter
# order (after the activation inputs) and the shard serialization order.
# ---------------------------------------------------------------------------


def embedding_specs(p: Profile) -> List[TensorSpec]:
    """Token embedding stage.

    bert: LN(tok[ids] + pos + type0)   gpt2/gptj/bart: tok[ids] + pos
    """
    H = p.hidden
    specs = [
        TensorSpec("tok_table", (p.vocab, H)),
        TensorSpec("pos_table", (p.max_seq, H)),
    ]
    if p.family == "bert":
        specs += [
            TensorSpec("type_table", (p.type_vocab, H)),
            TensorSpec("emb_ln_g", (H,)),
            TensorSpec("emb_ln_b", (H,)),
        ]
    return specs


def patch_embed_specs(p: Profile) -> List[TensorSpec]:
    """ViT patch embedding: linear projection + cls token + positions."""
    H = p.hidden
    return [
        TensorSpec("patch_w", (p.patch_dim, H)),
        TensorSpec("patch_b", (H,)),
        TensorSpec("cls_token", (1, H)),
        TensorSpec("pos_table", (p.max_seq, H)),
    ]


def encoder_layer_specs(p: Profile) -> List[TensorSpec]:
    """Standard transformer encoder layer (also GPT-2-style decoder layer).

    16 tensors: 2 LN pairs, QKVO projections with biases, 2-layer FFN.
    """
    H, F = p.hidden, p.ffn
    return [
        TensorSpec("ln1_g", (H,)),
        TensorSpec("ln1_b", (H,)),
        TensorSpec("wq", (H, H)),
        TensorSpec("bq", (H,)),
        TensorSpec("wk", (H, H)),
        TensorSpec("bk", (H,)),
        TensorSpec("wv", (H, H)),
        TensorSpec("bv", (H,)),
        TensorSpec("wo", (H, H)),
        TensorSpec("bo", (H,)),
        TensorSpec("ln2_g", (H,)),
        TensorSpec("ln2_b", (H,)),
        TensorSpec("w1", (H, F)),
        TensorSpec("b1", (F,)),
        TensorSpec("w2", (F, H)),
        TensorSpec("b2", (H,)),
    ]


# GPT-2 decoder layers share the encoder-layer parameterization (the causal
# mask is baked into the HLO, not a weight).
decoder_layer_specs = encoder_layer_specs


def gptj_layer_specs(p: Profile) -> List[TensorSpec]:
    """GPT-J block: single LN, parallel attention + FFN, no QKV biases."""
    H, F = p.hidden, p.ffn
    return [
        TensorSpec("ln_g", (H,)),
        TensorSpec("ln_b", (H,)),
        TensorSpec("wq", (H, H)),
        TensorSpec("wk", (H, H)),
        TensorSpec("wv", (H, H)),
        TensorSpec("wo", (H, H)),
        TensorSpec("w1", (H, F)),
        TensorSpec("b1", (F,)),
        TensorSpec("w2", (F, H)),
        TensorSpec("b2", (H,)),
    ]


def cross_decoder_layer_specs(p: Profile) -> List[TensorSpec]:
    """BART decoder layer: self-attn + cross-attn + FFN (post-LN)."""
    H, F = p.hidden, p.ffn
    return [
        TensorSpec("ln1_g", (H,)),
        TensorSpec("ln1_b", (H,)),
        TensorSpec("wq", (H, H)),
        TensorSpec("bq", (H,)),
        TensorSpec("wk", (H, H)),
        TensorSpec("bk", (H,)),
        TensorSpec("wv", (H, H)),
        TensorSpec("bv", (H,)),
        TensorSpec("wo", (H, H)),
        TensorSpec("bo", (H,)),
        TensorSpec("ln2_g", (H,)),
        TensorSpec("ln2_b", (H,)),
        TensorSpec("xwq", (H, H)),
        TensorSpec("xbq", (H,)),
        TensorSpec("xwk", (H, H)),
        TensorSpec("xbk", (H,)),
        TensorSpec("xwv", (H, H)),
        TensorSpec("xbv", (H,)),
        TensorSpec("xwo", (H, H)),
        TensorSpec("xbo", (H,)),
        TensorSpec("ln3_g", (H,)),
        TensorSpec("ln3_b", (H,)),
        TensorSpec("w1", (H, F)),
        TensorSpec("b1", (F,)),
        TensorSpec("w2", (F, H)),
        TensorSpec("b2", (H,)),
    ]


def pooler_specs(p: Profile) -> List[TensorSpec]:
    H = p.hidden
    return [TensorSpec("pool_w", (H, H)), TensorSpec("pool_b", (H,))]


def classifier_specs(p: Profile) -> List[TensorSpec]:
    H = p.hidden
    return [
        TensorSpec("cls_ln_g", (H,)),
        TensorSpec("cls_ln_b", (H,)),
        TensorSpec("cls_w", (H, p.num_classes)),
        TensorSpec("cls_b", (p.num_classes,)),
    ]


def lm_head_specs(p: Profile) -> List[TensorSpec]:
    H = p.hidden
    specs = [TensorSpec("f_ln_g", (H,)), TensorSpec("f_ln_b", (H,))]
    # GPT-2 ties the LM head to the token table; GPT-J has a separate head
    # with bias.  Either way the tensor is stored in this stage's shard
    # (layer-based partitioning: each stage's weights live in its own shard).
    specs.append(TensorSpec("head_w", (H, p.vocab)))
    if p.family == "gptj":
        specs.append(TensorSpec("head_b", (p.vocab,)))
    return specs


SPEC_FNS = {
    "embedding": embedding_specs,
    "patch_embed": patch_embed_specs,
    "encoder_layer": encoder_layer_specs,
    "decoder_layer": decoder_layer_specs,
    "gptj_layer": gptj_layer_specs,
    "cross_decoder_layer": cross_decoder_layer_specs,
    "pooler": pooler_specs,
    "classifier": classifier_specs,
    "lm_head": lm_head_specs,
    # Incremental-decode entry variants take the SAME weight list as their
    # base kind — one stage shard feeds both executables (the prime entries
    # simply leave their unused tensors as dead HLO parameters).
    "embedding_inc": embedding_specs,
    "decoder_layer_inc": decoder_layer_specs,
    "gptj_layer_inc": gptj_layer_specs,
    "decoder_layer_kv": decoder_layer_specs,
    "gptj_layer_kv": gptj_layer_specs,
    "lm_head_inc": lm_head_specs,
}


def layer_kinds_for(p: Profile) -> List[str]:
    """The distinct layer kinds a profile needs HLO entries for."""
    if p.family == "bert":
        return ["embedding", "encoder_layer", "pooler"]
    if p.family == "vit":
        return ["patch_embed", "encoder_layer", "classifier"]
    if p.family == "gpt2":
        return ["embedding", "decoder_layer", "lm_head"]
    if p.family == "gptj":
        return ["embedding", "gptj_layer", "lm_head"]
    if p.family == "bart":
        return ["embedding", "encoder_layer", "cross_decoder_layer", "lm_head"]
    raise ValueError(f"unknown family {p.family}")


def aux_entry_kinds_for(p: Profile) -> List[str]:
    """Extra HLO entries lowered beyond the stage kinds: the incremental
    single-token decode path (GPT-style families only; the Rust kvcache
    subsystem drives these)."""
    if p.family == "gpt2":
        return ["embedding_inc", "decoder_layer_inc", "decoder_layer_kv",
                "lm_head_inc"]
    if p.family == "gptj":
        return ["embedding_inc", "gptj_layer_inc", "gptj_layer_kv",
                "lm_head_inc"]
    return []


def stage_table(p: Profile) -> List[dict]:
    """Ordered pipeline stages for inference (what Rust executes).

    Each stage: {"index", "kind", "shard"}.  The encoder/decoder stages are
    the ones PIPELOAD's Loading Agents stream and the Daemon destroys; the
    first/last stages ride the same machinery (paper section III-B: the
    layer-based partitioning covers embedding/other layers too).
    """
    stages: List[dict] = []

    def add(kind: str):
        i = len(stages)
        stages.append({"index": i, "kind": kind, "shard": f"stage_{i:03d}.hws"})

    if p.family == "vit":
        add("patch_embed")
    else:
        add("embedding")
    if p.family == "bart":
        for _ in range(p.layers):
            add("encoder_layer")
        for _ in range(p.decoder_layers):
            add("cross_decoder_layer")
    else:
        body = {
            "bert": "encoder_layer",
            "vit": "encoder_layer",
            "gpt2": "decoder_layer",
            "gptj": "gptj_layer",
        }[p.family]
        for _ in range(p.layers):
            add(body)
    tail = {"bert": "pooler", "vit": "classifier", "gpt2": "lm_head",
            "gptj": "lm_head", "bart": "lm_head"}
    add(tail[p.family])
    return stages


def profile_total_bytes(p: Profile) -> int:
    """Total weight bytes across all stages (Table I 'total')."""
    total = 0
    for st in stage_table(p):
        for spec in SPEC_FNS[st["kind"]](p):
            total += spec.num_bytes()
    return total
