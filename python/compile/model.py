"""L2: per-layer-type JAX forward functions (build-time only).

Each function below becomes one AOT-compiled HLO entry: the activation(s)
come first, then the layer's weight tensors in the exact order of
``configs.SPEC_FNS[kind]``.  Weights are *runtime parameters* — never baked
into the executable — which is what lets PIPELOAD's Daemon Agent destroy
them after compute (DESIGN.md section 2).

The attention hot-spot always goes through the L1 Pallas kernel
(`kernels.attention`); LayerNorm/FFN can optionally use their Pallas
versions too (`KernelChoice`, ablated in rust/benches/ablation.rs).

``full_forward`` chains every stage exactly as the Rust Inference Agent
does, and is the oracle for the cross-language golden tests.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

from . import configs
from .configs import Profile
from .kernels import attention as attn_k
from .kernels import ffn as ffn_k
from .kernels import layernorm as ln_k
from .kernels.ref import LN_EPS


@dataclass(frozen=True)
class KernelChoice:
    """Which compute paths use the Pallas kernels vs plain jnp."""

    attention: bool = True
    layernorm: bool = False
    ffn: bool = False


DEFAULT_KERNELS = KernelChoice()


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def _ln(x: jax.Array, g: jax.Array, b: jax.Array, kc: KernelChoice) -> jax.Array:
    """LayerNorm over the last dim of [..., H]."""
    if kc.layernorm:
        flat = x.reshape((-1, x.shape[-1]))
        return ln_k.layernorm(flat, g, b).reshape(x.shape)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + LN_EPS) * g + b


def _ffn(x: jax.Array, w1, b1, w2, b2, kc: KernelChoice) -> jax.Array:
    if kc.ffn:
        flat = x.reshape((-1, x.shape[-1]))
        return ffn_k.ffn(flat, w1, b1, w2, b2).reshape(x.shape)
    h = jax.nn.gelu(x @ w1 + b1, approximate=False)
    return h @ w2 + b2


def _mha(p: Profile, q_in: jax.Array, kv_in: jax.Array, wq, bq, wk, bk, wv, bv,
         wo, bo, causal: bool, kc: KernelChoice) -> jax.Array:
    """Multi-head attention [B,S,H] x [B,Sk,H] -> [B,S,H].

    Heads are folded into the leading dim for the Pallas kernel.
    """
    B, S, H = q_in.shape
    Sk = kv_in.shape[1]
    nh, dh = p.heads, p.head_dim

    def split(x, w, bias, s):
        y = x @ w + bias  # [B,s,H]
        return y.reshape(B, s, nh, dh).transpose(0, 2, 1, 3).reshape(B * nh, s, dh)

    q = split(q_in, wq, bq, S)
    k = split(kv_in, wk, bk, Sk)
    v = split(kv_in, wv, bv, Sk)
    if kc.attention:
        if S == Sk:
            o = attn_k.attention(q, k, v, causal=causal)
        else:
            # cross-attention with different kv length: jnp fallback
            from .kernels.ref import attention_ref

            o = attention_ref(q, k, v, causal=False)
    else:
        from .kernels.ref import attention_ref

        o = attention_ref(q, k, v, causal)
    o = o.reshape(B, nh, S, dh).transpose(0, 2, 1, 3).reshape(B, S, H)
    return o @ wo + bo


def _mha_nobias(p: Profile, x: jax.Array, wq, wk, wv, wo, causal: bool,
                kc: KernelChoice) -> jax.Array:
    """GPT-J style attention: no QKV/O biases."""
    B, S, H = x.shape
    z = jnp.zeros((H,), x.dtype)
    # reuse _mha with zero biases; wo bias zero too
    return _mha(p, x, x, wq, z, wk, z, wv, z, wo, z, causal, kc)


# ---------------------------------------------------------------------------
# layer-kind forward fns: fwd(p, kc) -> callable(acts..., *params) -> out
# ---------------------------------------------------------------------------


def embedding_fwd(p: Profile, ids: jax.Array, *w, kc: KernelChoice = DEFAULT_KERNELS):
    """ids[B,S] int32 -> x[B,S,H]."""
    S = ids.shape[1]
    if p.family == "bert":
        tok, pos, typ, g, b = w
        x = tok[ids] + pos[:S][None, :, :] + typ[0][None, None, :]
        return _ln(x, g, b, kc)
    tok, pos = w
    return tok[ids] + pos[:S][None, :, :]


def patch_embed_fwd(p: Profile, patches: jax.Array, *w, kc: KernelChoice = DEFAULT_KERNELS):
    """patches[B,S-1,P] -> x[B,S,H] (cls token prepended)."""
    pw, pb, cls, pos = w
    B = patches.shape[0]
    x = patches @ pw + pb  # [B,S-1,H]
    cls_tok = jnp.broadcast_to(cls[None, :, :], (B, 1, p.hidden))
    x = jnp.concatenate([cls_tok, x], axis=1)
    S = x.shape[1]
    return x + pos[:S][None, :, :]


def encoder_layer_fwd(p: Profile, x: jax.Array, *w, causal: bool = False,
                      kc: KernelChoice = DEFAULT_KERNELS):
    """Standard transformer block; pre-LN (ViT/GPT-2) or post-LN (BERT)."""
    (ln1_g, ln1_b, wq, bq, wk, bk, wv, bv, wo, bo,
     ln2_g, ln2_b, w1, b1, w2, b2) = w
    if p.pre_ln:
        h = _ln(x, ln1_g, ln1_b, kc)
        x = x + _mha(p, h, h, wq, bq, wk, bk, wv, bv, wo, bo, causal, kc)
        h = _ln(x, ln2_g, ln2_b, kc)
        x = x + _ffn(h, w1, b1, w2, b2, kc)
    else:
        a = _mha(p, x, x, wq, bq, wk, bk, wv, bv, wo, bo, causal, kc)
        x = _ln(x + a, ln1_g, ln1_b, kc)
        f = _ffn(x, w1, b1, w2, b2, kc)
        x = _ln(x + f, ln2_g, ln2_b, kc)
    return x


def decoder_layer_fwd(p: Profile, x: jax.Array, *w, kc: KernelChoice = DEFAULT_KERNELS):
    return encoder_layer_fwd(p, x, *w, causal=True, kc=kc)


def gptj_layer_fwd(p: Profile, x: jax.Array, *w, kc: KernelChoice = DEFAULT_KERNELS):
    """GPT-J block: one LN, attention and FFN in parallel off the same LN."""
    ln_g, ln_b, wq, wk, wv, wo, w1, b1, w2, b2 = w
    h = _ln(x, ln_g, ln_b, kc)
    a = _mha_nobias(p, h, wq, wk, wv, wo, causal=True, kc=kc)
    f = _ffn(h, w1, b1, w2, b2, kc)
    return x + a + f


def cross_decoder_layer_fwd(p: Profile, x: jax.Array, enc: jax.Array, *w,
                            kc: KernelChoice = DEFAULT_KERNELS):
    """BART decoder block: self-attn, cross-attn, FFN (post-LN)."""
    (ln1_g, ln1_b, wq, bq, wk, bk, wv, bv, wo, bo,
     ln2_g, ln2_b, xwq, xbq, xwk, xbk, xwv, xbv, xwo, xbo,
     ln3_g, ln3_b, w1, b1, w2, b2) = w
    a = _mha(p, x, x, wq, bq, wk, bk, wv, bv, wo, bo, True, kc)
    x = _ln(x + a, ln1_g, ln1_b, kc)
    a = _mha(p, x, enc, xwq, xbq, xwk, xbk, xwv, xbv, xwo, xbo, False, kc)
    x = _ln(x + a, ln2_g, ln2_b, kc)
    f = _ffn(x, w1, b1, w2, b2, kc)
    return _ln(x + f, ln3_g, ln3_b, kc)


# ---------------------------------------------------------------------------
# incremental-decode entries (the Rust kvcache subsystem's compute)
#
# GPT-style decode with a KV cache runs three entry variants per token:
#   embedding_inc      ids[B,1] + pos[1]                  -> x[B,1,H]
#   <body>_inc         x[B,1,H] + K/V[B,S,H] + pos[1]     -> [B,3,H]
#                      (concat of x_out / k_new / v_new along axis 1 — one
#                       output array keeps the Rust execute path untouched)
#   lm_head_inc        x[B,1,H]                           -> logits[B,1,V]
# plus one prime entry run during the full-prefix pass to seed the cache:
#   <body>_kv          x[B,S,H]                           -> [B,2S,H]
#                      (concat of K / V along axis 1, all positions)
#
# The K/V cache tensors arrive zero-padded past `pos`; attention masks
# scores to positions <= pos, so the padding never leaks into the softmax.
# Weight parameter lists are identical to the base layer kind (the prime
# entry simply ignores the tensors it does not use), so the same stage
# shard feeds both the full and the incremental executables.
# ---------------------------------------------------------------------------


def _mha_cached(p: Profile, h: jax.Array, k_full: jax.Array, v_full: jax.Array,
                pos: jax.Array, wq, bq, wo, bo) -> jax.Array:
    """One-token attention over a cached K/V prefix.

    h: [B,1,H] (LN'd input); k/v_full: [B,S,H] valid at positions <= pos.
    Plain jnp (no Pallas): the kernel is shaped for S x S self-attention,
    and a 1 x S masked read is a trivial matmul either way.
    """
    B, _, H = h.shape
    S = k_full.shape[1]
    nh, dh = p.heads, p.head_dim

    def split(x, s):
        return x.reshape(B, s, nh, dh).transpose(0, 2, 1, 3).reshape(B * nh, s, dh)

    q = split(h @ wq + bq, 1)
    k = split(k_full, S)
    v = split(v_full, S)
    scores = (q @ k.transpose(0, 2, 1)) / jnp.sqrt(jnp.float32(dh))  # [B*nh,1,S]
    mask = jnp.arange(S)[None, None, :] <= pos[0]
    scores = jnp.where(mask, scores, jnp.float32(-1e30))
    w = jax.nn.softmax(scores, axis=-1)
    o = (w @ v).reshape(B, nh, 1, dh).transpose(0, 2, 1, 3).reshape(B, 1, H)
    return o @ wo + bo


def embedding_inc_fwd(p: Profile, ids: jax.Array, pos: jax.Array, *w,
                      kc: KernelChoice = DEFAULT_KERNELS):
    """One decode token's embedding: ids[B,1] at position pos[1] -> [B,1,H]."""
    tok, pos_table = w
    return tok[ids] + pos_table[pos][None, :, :]


def decoder_layer_inc_fwd(p: Profile, x: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, pos: jax.Array, *w,
                          kc: KernelChoice = DEFAULT_KERNELS):
    """GPT-2 block, one token against a cached prefix -> [B,3,H]
    (x_out / k_new / v_new concatenated along axis 1)."""
    (ln1_g, ln1_b, wq, bq, wk, bk, wv, bv, wo, bo,
     ln2_g, ln2_b, w1, b1, w2, b2) = w
    p0 = pos[0]
    h = _ln(x, ln1_g, ln1_b, kc)
    k_new = h @ wk + bk
    v_new = h @ wv + bv
    k_full = jax.lax.dynamic_update_slice(k_cache, k_new, (0, p0, 0))
    v_full = jax.lax.dynamic_update_slice(v_cache, v_new, (0, p0, 0))
    x = x + _mha_cached(p, h, k_full, v_full, pos, wq, bq, wo, bo)
    h2 = _ln(x, ln2_g, ln2_b, kc)
    x = x + _ffn(h2, w1, b1, w2, b2, kc)
    return jnp.concatenate([x, k_new, v_new], axis=1)


def gptj_layer_inc_fwd(p: Profile, x: jax.Array, k_cache: jax.Array,
                       v_cache: jax.Array, pos: jax.Array, *w,
                       kc: KernelChoice = DEFAULT_KERNELS):
    """GPT-J block, one token against a cached prefix -> [B,3,H]."""
    ln_g, ln_b, wq, wk, wv, wo, w1, b1, w2, b2 = w
    p0 = pos[0]
    z = jnp.zeros((p.hidden,), x.dtype)
    h = _ln(x, ln_g, ln_b, kc)
    k_new = h @ wk
    v_new = h @ wv
    k_full = jax.lax.dynamic_update_slice(k_cache, k_new, (0, p0, 0))
    v_full = jax.lax.dynamic_update_slice(v_cache, v_new, (0, p0, 0))
    a = _mha_cached(p, h, k_full, v_full, pos, wq, z, wo, z)
    f = _ffn(h, w1, b1, w2, b2, kc)
    return jnp.concatenate([x + a + f, k_new, v_new], axis=1)


def decoder_layer_kv_fwd(p: Profile, x: jax.Array, *w,
                         kc: KernelChoice = DEFAULT_KERNELS):
    """Prime entry: the GPT-2 layer's K/V for every position -> [B,2S,H]."""
    ln1_g, ln1_b = w[0], w[1]
    wk, bk, wv, bv = w[4], w[5], w[6], w[7]
    h = _ln(x, ln1_g, ln1_b, kc)
    return jnp.concatenate([h @ wk + bk, h @ wv + bv], axis=1)


def gptj_layer_kv_fwd(p: Profile, x: jax.Array, *w,
                      kc: KernelChoice = DEFAULT_KERNELS):
    """Prime entry: the GPT-J layer's K/V for every position -> [B,2S,H]."""
    ln_g, ln_b, wq, wk, wv = w[0], w[1], w[2], w[3], w[4]
    h = _ln(x, ln_g, ln_b, kc)
    return jnp.concatenate([h @ wk, h @ wv], axis=1)


def pooler_fwd(p: Profile, x: jax.Array, *w, kc: KernelChoice = DEFAULT_KERNELS):
    """BERT pooler: tanh(x[:,0] @ W + b) -> [B,H]."""
    pw, pb = w
    return jnp.tanh(x[:, 0, :] @ pw + pb)


def classifier_fwd(p: Profile, x: jax.Array, *w, kc: KernelChoice = DEFAULT_KERNELS):
    """ViT head: LN then linear on the cls token -> [B,C]."""
    g, b, cw, cb = w
    h = _ln(x, g, b, kc)
    return h[:, 0, :] @ cw + cb


def lm_head_fwd(p: Profile, x: jax.Array, *w, kc: KernelChoice = DEFAULT_KERNELS):
    """Final LN + LM projection -> logits[B,S,V]."""
    if p.family == "gptj":
        g, b, hw, hb = w
        return _ln(x, g, b, kc) @ hw + hb
    g, b, hw = w
    return _ln(x, g, b, kc) @ hw


FWD_FNS = {
    "embedding": embedding_fwd,
    "patch_embed": patch_embed_fwd,
    "encoder_layer": encoder_layer_fwd,
    "decoder_layer": decoder_layer_fwd,
    "gptj_layer": gptj_layer_fwd,
    "cross_decoder_layer": cross_decoder_layer_fwd,
    "pooler": pooler_fwd,
    "classifier": classifier_fwd,
    "lm_head": lm_head_fwd,
    # incremental-decode variants (Rust kvcache subsystem)
    "embedding_inc": embedding_inc_fwd,
    "decoder_layer_inc": decoder_layer_inc_fwd,
    "gptj_layer_inc": gptj_layer_inc_fwd,
    "decoder_layer_kv": decoder_layer_kv_fwd,
    "gptj_layer_kv": gptj_layer_kv_fwd,
    "lm_head_inc": lm_head_fwd,  # LN + projection is shape-agnostic
}


# ---------------------------------------------------------------------------
# activation specs per kind (what the HLO entry takes / returns)
# ---------------------------------------------------------------------------


def activation_in_specs(p: Profile, kind: str, batch: int) -> List[dict]:
    """Ordered activation inputs for an HLO entry (before the weights)."""
    B, S, H = batch, p.max_seq, p.hidden
    if kind == "embedding":
        return [{"name": "ids", "shape": [B, S], "dtype": "i32"}]
    if kind == "embedding_inc":
        return [
            {"name": "ids", "shape": [B, 1], "dtype": "i32"},
            {"name": "pos", "shape": [1], "dtype": "i32"},
        ]
    if kind in ("decoder_layer_inc", "gptj_layer_inc"):
        return [
            {"name": "x", "shape": [B, 1, H], "dtype": "f32"},
            {"name": "k_cache", "shape": [B, S, H], "dtype": "f32"},
            {"name": "v_cache", "shape": [B, S, H], "dtype": "f32"},
            {"name": "pos", "shape": [1], "dtype": "i32"},
        ]
    if kind == "lm_head_inc":
        return [{"name": "x", "shape": [B, 1, H], "dtype": "f32"}]
    if kind == "patch_embed":
        return [{"name": "patches", "shape": [B, S - 1, p.patch_dim], "dtype": "f32"}]
    if kind == "cross_decoder_layer":
        return [
            {"name": "x", "shape": [B, S, H], "dtype": "f32"},
            {"name": "enc", "shape": [B, S, H], "dtype": "f32"},
        ]
    return [{"name": "x", "shape": [B, S, H], "dtype": "f32"}]


def activation_out_spec(p: Profile, kind: str, batch: int) -> dict:
    B, S, H = batch, p.max_seq, p.hidden
    if kind == "pooler":
        return {"name": "pooled", "shape": [B, H], "dtype": "f32"}
    if kind == "classifier":
        return {"name": "logits", "shape": [B, p.num_classes], "dtype": "f32"}
    if kind == "lm_head":
        return {"name": "logits", "shape": [B, S, p.vocab], "dtype": "f32"}
    if kind == "lm_head_inc":
        return {"name": "logits", "shape": [B, 1, p.vocab], "dtype": "f32"}
    if kind == "embedding_inc":
        return {"name": "x", "shape": [B, 1, H], "dtype": "f32"}
    if kind in ("decoder_layer_inc", "gptj_layer_inc"):
        # x_out / k_new / v_new stacked along axis 1
        return {"name": "xkv", "shape": [B, 3, H], "dtype": "f32"}
    if kind in ("decoder_layer_kv", "gptj_layer_kv"):
        # K / V for all positions stacked along axis 1
        return {"name": "kv", "shape": [B, 2 * S, H], "dtype": "f32"}
    return {"name": "x", "shape": [B, S, H], "dtype": "f32"}


# ---------------------------------------------------------------------------
# full-model forward (golden oracle; mirrors the Rust per-stage chain)
# ---------------------------------------------------------------------------


def full_forward(p: Profile, inputs: jax.Array, stage_weights: Sequence[Sequence[jax.Array]],
                 kc: KernelChoice = DEFAULT_KERNELS) -> jax.Array:
    """Chain all stages like the Inference Agent does (non-BART)."""
    stages = configs.stage_table(p)
    assert len(stage_weights) == len(stages)
    x = inputs
    enc_out = None
    enc_done = 0
    for st, w in zip(stages, stage_weights):
        kind = st["kind"]
        if kind == "cross_decoder_layer":
            if enc_out is None:
                enc_out = x
                # BART: decoder consumes embedded decoder ids; for the
                # extension we feed the encoder output as the decoder input
                # seed as well (simplified single-input seq2seq trace).
                x = enc_out
            x = cross_decoder_layer_fwd(p, x, enc_out, *w, kc=kc)
        else:
            x = FWD_FNS[kind](p, x, *w, kc=kc)
        enc_done += 1
    return x


def make_example_weights(p: Profile, kind: str, rng) -> List[jax.Array]:
    """Random-normal weights (scaled) for a layer kind, numpy RandomState."""
    out = []
    for spec in configs.SPEC_FNS[kind](p):
        arr = rng.randn(*spec.shape).astype("float32") * 0.05
        if spec.name.endswith("_g"):  # LN gains near 1
            arr = 1.0 + arr
        out.append(jnp.asarray(arr))
    return out
