"""Hermes Weight Shard (.hws) writer/reader — python side.

Binary layout (little-endian), mirrored exactly by ``rust/src/weights/``:

    magic   : 4 bytes  b"HWSH"
    version : u32      (1)
    kind    : u16 len + utf8 bytes          (layer kind, e.g. "encoder_layer")
    stage   : u32                           (stage index in the pipeline)
    count   : u32                           (number of tensors)
    per tensor header:
        name     : u16 len + utf8
        dtype    : u8   (0=f32, 1=i32, 2=u32, 3=f16)
        ndims    : u8
        dims     : u32 * ndims
        data_len : u64  (bytes)
    data    : concatenated raw tensor data in header order
    footer  : u64 fletcher64 checksum over all preceding bytes

The format is deliberately trivial: a shard is one pipeline stage's weights,
the unit PIPELOAD's Loading Agents stream and the Daemon destroys.
Interop is proven by ``python/tests/test_hws.py`` (python round-trip) and
``rust/tests/golden_numerics.rs`` (rust reads python-written shards).
"""

from __future__ import annotations

import struct
from typing import List, Tuple

import numpy as np

MAGIC = b"HWSH"
VERSION = 1
DTYPE_CODES = {"f32": 0, "i32": 1, "u32": 2, "f16": 3}
DTYPE_NP = {"f32": np.float32, "i32": np.int32, "u32": np.uint32, "f16": np.float16}
CODE_TO_DTYPE = {v: k for k, v in DTYPE_CODES.items()}


def fletcher64(data: bytes) -> int:
    """Fletcher-64 over little-endian u32 words (zero-padded tail)."""
    if len(data) % 4:
        data = data + b"\x00" * (4 - len(data) % 4)
    a, b = 0, 0
    m = (1 << 32) - 1
    for (w,) in struct.iter_unpack("<I", data):
        a = (a + w) % m
        b = (b + a) % m
    return (b << 32) | a


def write_shard(path: str, kind: str, stage: int,
                tensors: List[Tuple[str, np.ndarray]]) -> int:
    """Write one shard; returns total bytes written."""
    head = bytearray()
    head += MAGIC
    head += struct.pack("<I", VERSION)
    kb = kind.encode()
    head += struct.pack("<H", len(kb)) + kb
    head += struct.pack("<I", stage)
    head += struct.pack("<I", len(tensors))
    blobs = []
    for name, arr in tensors:
        arr = np.ascontiguousarray(arr)
        dt = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32",
              np.dtype(np.uint32): "u32", np.dtype(np.float16): "f16"}[arr.dtype]
        nb = name.encode()
        head += struct.pack("<H", len(nb)) + nb
        head += struct.pack("<BB", DTYPE_CODES[dt], arr.ndim)
        head += struct.pack(f"<{arr.ndim}I", *arr.shape)
        raw = arr.tobytes()
        head += struct.pack("<Q", len(raw))
        blobs.append(raw)
    body = bytes(head) + b"".join(blobs)
    csum = fletcher64(body)
    with open(path, "wb") as f:
        f.write(body)
        f.write(struct.pack("<Q", csum))
    return len(body) + 8


def read_shard(path: str):
    """Read a shard -> (kind, stage, [(name, ndarray)]). Verifies checksum."""
    with open(path, "rb") as f:
        data = f.read()
    body, footer = data[:-8], data[-8:]
    (want,) = struct.unpack("<Q", footer)
    got = fletcher64(body)
    if want != got:
        raise ValueError(f"checksum mismatch in {path}: {want:#x} != {got:#x}")
    off = 0

    def take(fmt):
        nonlocal off
        size = struct.calcsize(fmt)
        vals = struct.unpack_from(fmt, body, off)
        off += size
        return vals

    magic = body[:4]
    off = 4
    assert magic == MAGIC, magic
    (version,) = take("<I")
    assert version == VERSION
    (klen,) = take("<H")
    kind = body[off:off + klen].decode()
    off += klen
    (stage,) = take("<I")
    (count,) = take("<I")
    headers = []
    for _ in range(count):
        (nlen,) = take("<H")
        name = body[off:off + nlen].decode()
        off += nlen
        code, ndim = take("<BB")
        dims = take(f"<{ndim}I") if ndim else ()
        (dlen,) = take("<Q")
        headers.append((name, CODE_TO_DTYPE[code], dims, dlen))
    tensors = []
    for name, dt, dims, dlen in headers:
        raw = body[off:off + dlen]
        off += dlen
        arr = np.frombuffer(raw, dtype=DTYPE_NP[dt]).reshape(dims)
        tensors.append((name, arr))
    assert off == len(body), (off, len(body))
    return kind, stage, tensors
