"""AOT compile path: lower every (profile x layer-kind x batch) to HLO text.

Runs ONCE at build time (``make artifacts``); python never appears on the
request path.  Interchange format is **HLO text**, not serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that
xla_extension 0.5.1 (the version the published ``xla`` 0.1.6 crate binds)
rejects; the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Outputs under ``artifacts/``:

    <profile>/<kind>.b<B>.hlo.txt     one executable per layer kind/batch
    manifest.json                     configs + stage tables + tensor specs
                                      + entry index (Rust's single source
                                      of truth — it never re-derives specs)
    golden/<profile>/...              python-written shards + input/expected
                                      vectors for cross-language numerics
                                      tests (tiny profiles only)

Usage: python -m compile.aot [--out-dir DIR] [--profiles a,b] [--golden-only]
       [--pallas-ln] [--pallas-ffn]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import zlib

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, hws, model
from .configs import Profile
from .model import KernelChoice


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    return_tuple=False: every entry has exactly one output array, so the
    Rust side can chain PJRT output buffers directly into the next layer's
    execute_b call (no tuple unwrap, no literal round-trip).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def entry_fn(p: Profile, kind: str, kc: KernelChoice):
    """Build the jittable fn: (activations..., *weights) -> (out,)."""
    fwd = model.FWD_FNS[kind]
    n_act = len(model.activation_in_specs(p, kind, 1))

    def fn(*args):
        acts, params = args[:n_act], args[n_act:]
        return fwd(p, *acts, *params, kc=kc)

    return fn


_DT = {"f32": np.float32, "i32": np.int32, "u32": np.uint32}


def lower_entry(p: Profile, kind: str, batch: int, kc: KernelChoice) -> str:
    act_specs = model.activation_in_specs(p, kind, batch)
    arg_specs = [
        jax.ShapeDtypeStruct(tuple(a["shape"]), _DT[a["dtype"]]) for a in act_specs
    ]
    for spec in configs.SPEC_FNS[kind](p):
        arg_specs.append(jax.ShapeDtypeStruct(spec.shape, _DT[spec.dtype]))
    # keep_unused: the Rust side always passes a stage shard's FULL tensor
    # list; entries that use a subset (the *_kv prime entries) must keep the
    # unused weights as dead parameters or the arity would not match.
    lowered = jax.jit(entry_fn(p, kind, kc), keep_unused=True).lower(*arg_specs)
    return to_hlo_text(lowered)


def build_profile(p: Profile, out_dir: str, kc: KernelChoice) -> dict:
    """Lower all entries for one profile; return its manifest block."""
    pdir = os.path.join(out_dir, p.name)
    os.makedirs(pdir, exist_ok=True)
    kinds = {}
    for kind in configs.layer_kinds_for(p):
        kinds[kind] = {
            "params": [s.to_json() for s in configs.SPEC_FNS[kind](p)],
            "param_bytes": sum(s.num_bytes() for s in configs.SPEC_FNS[kind](p)),
        }
    entries = {}
    for kind in configs.layer_kinds_for(p) + configs.aux_entry_kinds_for(p):
        for batch in p.batches:
            t0 = time.time()
            text = lower_entry(p, kind, batch, kc)
            rel = f"{p.name}/{kind}.b{batch}.hlo.txt"
            with open(os.path.join(out_dir, rel), "w") as f:
                f.write(text)
            entries[f"{kind}@b{batch}"] = {
                "kind": kind,
                "batch": batch,
                "hlo": rel,
                "activations": model.activation_in_specs(p, kind, batch),
                "output": model.activation_out_spec(p, kind, batch),
            }
            print(f"  lowered {p.name}/{kind}@b{batch} "
                  f"({len(text)//1024} KiB, {time.time()-t0:.1f}s)", flush=True)
    stages = configs.stage_table(p)
    return {
        "config": dict(p.raw, name=p.name),
        "stages": stages,
        "kinds": kinds,
        "entries": entries,
        "total_weight_bytes": configs.profile_total_bytes(p),
    }


# ---------------------------------------------------------------------------
# golden vectors (cross-language numerics ground truth, tiny profiles)
# ---------------------------------------------------------------------------

GOLDEN_PROFILES = ("tiny-bert", "tiny-gpt", "tiny-vit", "tiny-gptj")


def gen_golden(p: Profile, out_dir: str, kc: KernelChoice) -> None:
    gdir = os.path.join(out_dir, "golden", p.name)
    wdir = os.path.join(gdir, "weights")
    os.makedirs(wdir, exist_ok=True)
    # zlib.crc32, not hash(): str hashing is salted per process
    # (PYTHONHASHSEED), which would make `make artifacts` nondeterministic.
    rng = np.random.RandomState(zlib.crc32(p.name.encode()) % (2**31))
    stages = configs.stage_table(p)
    stage_weights = []
    for st in stages:
        w = model.make_example_weights(p, st["kind"], rng)
        specs = configs.SPEC_FNS[st["kind"]](p)
        hws.write_shard(
            os.path.join(wdir, st["shard"]), st["kind"], st["index"],
            [(s.name, np.asarray(t)) for s, t in zip(specs, w)],
        )
        stage_weights.append(w)
    B, S = 1, p.max_seq
    if p.family == "vit":
        inp = rng.randn(B, S - 1, p.patch_dim).astype(np.float32)
        in_spec = {"shape": [B, S - 1, p.patch_dim], "dtype": "f32"}
    else:
        inp = rng.randint(0, p.vocab, size=(B, S)).astype(np.int32)
        in_spec = {"shape": [B, S], "dtype": "i32"}
    out = np.asarray(model.full_forward(p, inp, stage_weights, kc=kc))
    inp.tofile(os.path.join(gdir, "input.bin"))
    out.astype(np.float32).tofile(os.path.join(gdir, "expected.bin"))
    with open(os.path.join(gdir, "golden.json"), "w") as f:
        json.dump({
            "profile": p.name,
            "input": in_spec,
            "expected": {"shape": list(out.shape), "dtype": "f32"},
            "rtol": 5e-4, "atol": 5e-5,
        }, f, indent=1)
    print(f"  golden {p.name}: out shape {out.shape}", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(configs.REPO_ROOT, "artifacts"))
    ap.add_argument("--profiles", default="",
                    help="comma-separated profile names (default: all)")
    ap.add_argument("--golden-only", action="store_true")
    ap.add_argument("--pallas-ln", action="store_true",
                    help="use the Pallas LayerNorm kernel in lowered HLO")
    ap.add_argument("--pallas-ffn", action="store_true",
                    help="use the Pallas FFN kernel in lowered HLO")
    args = ap.parse_args(argv)

    kc = KernelChoice(attention=True, layernorm=args.pallas_ln, ffn=args.pallas_ffn)
    profiles = configs.load_profiles()
    names = [n.strip() for n in args.profiles.split(",") if n.strip()] or list(profiles)
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"version": 1, "kernel_choice": vars(kc) if hasattr(kc, "__dict__") else {
        "attention": kc.attention, "layernorm": kc.layernorm, "ffn": kc.ffn},
        "profiles": {}}
    # dataclass(frozen) has no __dict__ mutation issues; build dict explicitly
    manifest["kernel_choice"] = {
        "attention": kc.attention, "layernorm": kc.layernorm, "ffn": kc.ffn}

    t0 = time.time()
    if not args.golden_only:
        # partial rebuilds merge into the existing manifest
        manifest_path = os.path.join(args.out_dir, "manifest.json")
        if os.path.exists(manifest_path) and set(names) != set(profiles):
            with open(manifest_path) as f:
                manifest["profiles"] = json.load(f).get("profiles", {})
        for name in names:
            p = profiles[name]
            print(f"profile {name}:", flush=True)
            manifest["profiles"][name] = build_profile(p, args.out_dir, kc)
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)
    for name in names:
        if name in GOLDEN_PROFILES:
            gen_golden(profiles[name], args.out_dir, kc)
    print(f"aot done in {time.time()-t0:.1f}s -> {args.out_dir}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
