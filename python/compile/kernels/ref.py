"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has an exact reference here; pytest
(`python/tests/test_kernels.py`) sweeps shapes with hypothesis and asserts
allclose between kernel and oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LN_EPS = 1e-5


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool) -> jax.Array:
    """Scaled dot-product attention over [BH, S, dh] (heads pre-folded)."""
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(jnp.float32(dh))
    if causal:
        S = q.shape[1]
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        s = jnp.where(mask[None, :, :], s, jnp.float32(-1e30))
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def layernorm_ref(x: jax.Array, g: jax.Array, b: jax.Array, eps: float = LN_EPS) -> jax.Array:
    """LayerNorm over the last dim of [R, H]."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * g + b


def ffn_ref(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array, b2: jax.Array) -> jax.Array:
    """Position-wise FFN with exact (erf) GELU over [R, H]."""
    h = jax.nn.gelu(x @ w1 + b1, approximate=False)
    return h @ w2 + b2
