"""L1 hot-spot: flash-style attention as a Pallas kernel.

Online-softmax attention tiled over (batch*heads, q-blocks) with an inner
loop over kv-blocks — the classic FlashAttention schedule re-expressed for
TPU Pallas:

* each grid step owns one q tile in scratch (VMEM on a real TPU);
* kv tiles stream through the inner `fori_loop`, maintaining the running
  max `m`, normalizer `l`, and accumulator `acc`;
* `BlockSpec`s express the HBM->VMEM schedule the CUDA original expressed
  with threadblocks (DESIGN.md section 4, Hardware-Adaptation).

On this image the kernel MUST run with ``interpret=True`` (the CPU PJRT
plugin cannot execute Mosaic custom-calls), so it lowers to plain HLO and
runs anywhere — including the Rust PJRT runtime.  TPU efficiency is
*estimated* from the BlockSpec footprint in DESIGN.md section 8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (block size picker)."""
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, block_k: int, seq: int):
    """One (bh, q-block) grid step: online softmax over kv blocks."""
    iq = pl.program_id(1)
    block_q = q_ref.shape[1]
    dh = q_ref.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    q = q_ref[0].astype(jnp.float32) * scale  # [bq, dh]

    num_k_blocks = seq // block_k
    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q)  # global q rows

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, pl.dslice(j * block_k, block_k), :]
        v = v_ref[0, pl.dslice(j * block_k, block_k), :]
        s = q @ k.astype(jnp.float32).T  # [bq, bk]
        if causal:
            k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = k_pos[None, :] <= q_pos[:, None]
            s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        # Rescale previous stats to the new max, then fold in this block.
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, dh), dtype=jnp.float32)
    if causal:
        # Blocks strictly above the diagonal contribute nothing; skip them.
        # (iq+1)*bq rows need kv up to that row index.
        last = jax.lax.div(((iq + 1) * block_q - 1), block_k) + 1
    else:
        last = num_k_blocks
    m, l, acc = jax.lax.fori_loop(0, last, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = False,
              block_q: int = 0, block_k: int = 0) -> jax.Array:
    """Flash attention over [BH, S, dh]; heads folded into the batch dim.

    block_q / block_k of 0 picks the largest divisor of S <= 32.
    """
    bh, seq, dh = q.shape
    bq = block_q or _largest_divisor_leq(seq, 32)
    bk = block_k or _largest_divisor_leq(seq, 32)
    assert seq % bq == 0 and seq % bk == 0, (seq, bq, bk)
    grid = (bh, seq // bq)
    kernel = functools.partial(_attn_kernel, causal=causal, block_k=bk, seq=seq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, dh), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k, v)


def vmem_footprint_bytes(seq: int, dh: int, block_q: int = 0, block_k: int = 0,
                         bytes_per_elem: int = 4) -> int:
    """Estimated per-grid-step VMEM footprint of the kernel (DESIGN section 8).

    q tile + streamed kv tiles + accumulator + softmax stats + output tile.
    """
    bq = block_q or _largest_divisor_leq(seq, 32)
    bk = block_k or _largest_divisor_leq(seq, 32)
    tiles = (
        bq * dh        # q
        + 2 * bk * dh  # k, v (streamed)
        + bq * dh      # acc
        + bq * bk      # scores
        + 2 * bq       # m, l
        + bq * dh      # o
    )
    return tiles * bytes_per_elem
