"""LayerNorm as a Pallas kernel, tiled over row blocks.

Used by the L2 model when ``use_pallas_ln`` is enabled (ablation path);
the default model uses the fused jnp LN which XLA fuses better on CPU.
Correctness vs `ref.layernorm_ref` is always enforced by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import LN_EPS


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [br, H]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


def _largest_divisor_leq(n: int, cap: int) -> int:
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def layernorm(x: jax.Array, g: jax.Array, b: jax.Array,
              block_rows: int = 0, eps: float = LN_EPS) -> jax.Array:
    """LayerNorm over the last dim of [R, H]."""
    rows, h = x.shape
    br = block_rows or _largest_divisor_leq(rows, 64)
    assert rows % br == 0
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, h), x.dtype),
        interpret=True,
    )(x, g, b)
