"""Fused position-wise FFN (matmul -> GELU -> matmul) as a Pallas kernel.

Row-blocked: each grid step pulls one row tile of x plus the full (small)
weight matrices into scratch, computes both matmuls and the activation
without materializing the [R, F] intermediate in HBM — the fusion the
paper's CPU baseline gets from oneDNN, expressed as an explicit schedule.
Ablation path (``use_pallas_ffn``); always tested vs `ref.ffn_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    h = x @ w1_ref[...].astype(jnp.float32) + b1_ref[...]
    h = jax.nn.gelu(h, approximate=False)
    o_ref[...] = (h @ w2_ref[...].astype(jnp.float32) + b2_ref[...]).astype(o_ref.dtype)


def _largest_divisor_leq(n: int, cap: int) -> int:
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


@functools.partial(jax.jit, static_argnames=("block_rows",))
def ffn(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array, b2: jax.Array,
        block_rows: int = 0) -> jax.Array:
    """Fused FFN over [R, H] with weights [H, F], [F], [F, H], [H]."""
    rows, hid = x.shape
    f = w1.shape[1]
    br = block_rows or _largest_divisor_leq(rows, 32)
    assert rows % br == 0
    return pl.pallas_call(
        _ffn_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, hid), lambda i: (i, 0)),
            pl.BlockSpec((hid, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, hid), lambda i: (0, 0)),
            pl.BlockSpec((hid,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, hid), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, hid), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)
